//! Shard conformance: the sharded sweep orchestration
//! (`coordinator::shard`) must reassemble results **byte-identical** to
//! an unsharded run.
//!
//! Three layers of proof:
//!
//! 1. **Boundary math** — property tests over random matrices and every
//!    `K/N` split (including `N = 1`, `N` larger than the matrix, and
//!    empty shards): the shard slices partition the cell list exactly
//!    once with no overlap, and every worker computes the same
//!    boundaries independently.
//! 2. **Differential byte-identity** — sweep (both engines, including a
//!    `--preset`-derived matrix) and workload (a `--synth`-style trace)
//!    runs sharded 1/3 + 2/3 + 3/3, merged, and compared byte-for-byte
//!    against the unsharded CSV and JSON sinks; plus a loop over many
//!    `K/N` splits.
//! 3. **Lifecycle** — resumability (a complete shard re-run is a no-op;
//!    after deleting one shard only that shard recomputes) and refusal
//!    (truncated/corrupt/missing shard files make `merge` fail, and the
//!    real binary exits non-zero).

use paraspawn::coordinator::shard::{self, ShardOutcome, ShardSpec};
use paraspawn::coordinator::sweep::{self, ClusterKind, Engine, ScenarioMatrix};
use paraspawn::coordinator::wsweep::{self, WorkloadMatrix, WorkloadSpec};
use paraspawn::util::rng::Rng;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

const THREADS: usize = 2;

const SWEEP_SINKS: [&str; 6] = [
    "sweep_summary.csv",
    "sweep_samples.csv",
    "sweep_phases.csv",
    "sweep_summary.json",
    "sweep_samples.json",
    "sweep_phases.json",
];
const WORKLOAD_SINKS: [&str; 4] = [
    "workload_summary.csv",
    "workload_jobs.csv",
    "workload_summary.json",
    "workload_jobs.json",
];

/// A fresh scratch directory unique to this test + process.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("paraspawn-shardconf-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("creating scratch dir");
    dir
}

fn assert_same_files(unsharded: &Path, merged: &Path, names: &[&str], what: &str) {
    for name in names {
        let a = std::fs::read(unsharded.join(name))
            .unwrap_or_else(|e| panic!("{what}: unsharded sink {name} missing: {e}"));
        let b = std::fs::read(merged.join(name))
            .unwrap_or_else(|e| panic!("{what}: merged sink {name} missing: {e}"));
        assert_eq!(
            a, b,
            "{what}: merged {name} is not byte-identical to the unsharded run"
        );
    }
}

// ---------------------------------------------------------------------------
// 1. Boundary math
// ---------------------------------------------------------------------------

#[test]
fn bounds_partition_every_length_exactly_once() {
    for len in 0..48usize {
        for count in 1..=13usize {
            let mut expect_start = 0usize;
            let mut sizes = Vec::new();
            for index in 1..=count {
                let spec = ShardSpec { index, count };
                let (start, end) = spec.bounds(len);
                assert_eq!(start, expect_start, "gap/overlap at shard {index}/{count}, len {len}");
                assert!(end >= start);
                sizes.push(end - start);
                expect_start = end;
            }
            assert_eq!(expect_start, len, "shards of {count} do not cover len {len}");
            // Balanced: sizes differ by at most one.
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced split for len {len}, count {count}: {sizes:?}");
            // N > len leaves exactly N - len shards empty.
            if count > len {
                assert_eq!(sizes.iter().filter(|&&s| s == 0).count(), count - len);
            }
        }
    }
}

#[test]
fn shard_spec_parse_accepts_and_rejects() {
    let s = ShardSpec::parse("2/3").expect("2/3 parses");
    assert_eq!((s.index, s.count), (2, 3));
    assert_eq!(s.dir_name(), "shard-2-of-3");
    assert_eq!(s.label(), "2/3");
    assert_eq!(ShardSpec::parse(" 1 / 1 ").expect("whitespace ok").count, 1);
    for bad in ["0/3", "4/3", "3", "x/y", "1/0", "/", ""] {
        assert!(ShardSpec::parse(bad).is_err(), "{bad:?} should be rejected");
    }
}

/// A random Mini-cluster matrix: random pair set, config subset, reps.
fn random_matrix(rng: &mut Rng) -> ScenarioMatrix {
    let all = sweep::mn5_expand_configs();
    let nconf = rng.usize_in(1, all.len() + 1);
    let mut pairs = BTreeSet::new();
    for _ in 0..rng.usize_in(1, 6) {
        // `i == n` pairs are legal inputs the expansion skips.
        pairs.insert((rng.usize_in(1, 5), rng.usize_in(1, 9)));
    }
    ScenarioMatrix::new()
        .clusters(vec![ClusterKind::Mini])
        .configs(all.into_iter().take(nconf).collect())
        .pairs(pairs.into_iter().collect())
        .reps(rng.usize_in(1, 4))
        .seed(rng.next_u64())
}

#[test]
fn random_matrices_cover_exactly_once_for_all_splits() {
    let mut rng = Rng::new(0x5eed_cafe);
    for trial in 0..25 {
        let m = random_matrix(&mut rng);
        let matrices = vec![m];
        let full: Vec<(sweep::CellKey, usize)> =
            matrices.iter().flat_map(|m| m.tasks()).map(|t| (t.cell, t.rep)).collect();
        let chunks = shard::sweep_cell_chunks(&matrices).expect("chunking succeeds");
        let ncells = chunks.len();
        for count in [1, 2, 3, 5, ncells.max(1), ncells + 4] {
            // Each worker recomputes the chunk list from the matrix
            // independently (as separate machines would) and takes only
            // its own slice; the reassembly must be the full task list.
            let mut union: Vec<(sweep::CellKey, usize)> = Vec::new();
            for index in 1..=count {
                let worker_chunks = shard::sweep_cell_chunks(&matrices).expect("worker chunking");
                let (start, end) = ShardSpec { index, count }.bounds(worker_chunks.len());
                assert_eq!(worker_chunks.len(), ncells, "workers disagree on the cell list");
                for (_, tasks) in &worker_chunks[start..end] {
                    union.extend(tasks.iter().map(|t| (t.cell.clone(), t.rep)));
                }
            }
            assert_eq!(
                union, full,
                "trial {trial}, {count} shards: union of slices is not the exact task list"
            );
        }
    }
}

#[test]
fn preset_group_chunks_are_whole_cells_and_unique() {
    // A multi-matrix preset group (the paper sweep) chunks cleanly:
    // repetitions never straddle a chunk, and cells are globally unique.
    let matrices = sweep::preset_group("mn5").expect("mn5 preset group exists");
    let chunks = shard::sweep_cell_chunks(&matrices).expect("preset group chunks");
    let mut seen = BTreeSet::new();
    for (cell, tasks) in &chunks {
        assert!(!tasks.is_empty());
        assert!(tasks.iter().all(|t| t.cell == *cell), "chunk mixes cells");
        let reps: Vec<usize> = tasks.iter().map(|t| t.rep).collect();
        assert_eq!(reps, (0..reps.len()).collect::<Vec<_>>(), "reps not contiguous");
        assert!(seen.insert(cell.clone()), "duplicate cell across the group");
    }
}

// ---------------------------------------------------------------------------
// 2. Differential byte-identity
// ---------------------------------------------------------------------------

/// A small Mini-cluster matrix that is cheap on the simulated engine.
fn mini_matrix() -> ScenarioMatrix {
    ScenarioMatrix::new()
        .clusters(vec![ClusterKind::Mini])
        .configs(sweep::mn5_expand_configs().into_iter().take(2).collect())
        .pairs(vec![(1, 2), (2, 4), (1, 4)])
        .reps(2)
        .seed(7)
}

/// Run `matrices` unsharded into `dir` (the exact single-machine path:
/// `run_tasks_engine` + `SweepResults::write`).
fn run_unsharded_sweep(matrices: &[ScenarioMatrix], engine: Engine, dir: &Path) {
    let tasks: Vec<sweep::SweepTask> = matrices.iter().flat_map(|m| m.tasks()).collect();
    let results = sweep::run_tasks_engine(tasks, THREADS, engine).expect("unsharded sweep");
    results.write(dir, true).expect("unsharded write");
}

/// Shard `matrices` 1/N..N/N into `root`, merge, and return the merged
/// run directory.
fn shard_and_merge_sweep(
    matrices: &[ScenarioMatrix],
    engine: Engine,
    root: &Path,
    count: usize,
) -> PathBuf {
    let mut run_dir = None;
    for index in 1..=count {
        let spec = ShardSpec { index, count };
        let report = shard::run_sweep_shard(matrices, engine, spec, root, true, THREADS)
            .unwrap_or_else(|e| panic!("shard {index}/{count}: {e:#}"));
        assert_eq!(report.outcome, ShardOutcome::Computed);
        run_dir = Some(report.run_dir);
    }
    let report = shard::merge_run(root).expect("merge succeeds");
    assert_eq!(report.shards, count);
    assert_eq!(report.run_dir, run_dir.expect("at least one shard ran"));
    report.run_dir
}

#[test]
fn sweep_merge_is_byte_identical_on_both_engines() {
    for engine in [Engine::Simulated, Engine::Analytic] {
        let dir = scratch(&format!("sweep-{}", engine.name()));
        let matrices = vec![mini_matrix()];
        let unsharded = dir.join("unsharded");
        run_unsharded_sweep(&matrices, engine, &unsharded);
        let merged = shard_and_merge_sweep(&matrices, engine, &dir.join("sharded"), 3);
        assert_same_files(&unsharded, &merged, &SWEEP_SINKS, engine.name());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn preset_matrix_merge_is_byte_identical() {
    // The CLI-preset path: `--preset 4a --max-nodes 4 --reps 2` on the
    // analytic engine, sharded 3 ways by a group of independent workers.
    let dir = scratch("preset");
    let m = sweep::preset("4a").expect("preset 4a exists").max_nodes(4).reps(2);
    let matrices = vec![m];
    let unsharded = dir.join("unsharded");
    run_unsharded_sweep(&matrices, Engine::Analytic, &unsharded);
    let merged = shard_and_merge_sweep(&matrices, Engine::Analytic, &dir.join("sharded"), 3);
    assert_same_files(&unsharded, &merged, &SWEEP_SINKS, "preset 4a");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_tested_kn_split_is_byte_identical() {
    // Analytic engine: cheap enough to prove byte-identity for many N,
    // including N = 1, N equal to the cell count, and N far beyond it
    // (some shards empty).
    let dir = scratch("splits");
    let matrices = vec![mini_matrix()];
    let ncells = shard::sweep_cell_chunks(&matrices).expect("chunks").len();
    let unsharded = dir.join("unsharded");
    run_unsharded_sweep(&matrices, Engine::Analytic, &unsharded);
    for count in [1, 2, 3, 4, ncells, ncells + 5] {
        let root = dir.join(format!("n{count}"));
        let merged = shard_and_merge_sweep(&matrices, Engine::Analytic, &root, count);
        assert_same_files(&unsharded, &merged, &SWEEP_SINKS, &format!("{count} shards"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A small workload matrix over a `--synth`-style trace (the same
/// generator as `paraspawn workload --synth`).
fn synth_workload_matrix() -> WorkloadMatrix {
    let total_nodes = ClusterKind::Mini.cluster().len();
    let mut m = WorkloadMatrix::for_kind(ClusterKind::Mini);
    m.pricers = wsweep::scalar_pricers(&wsweep::default_costs());
    m.workloads = vec![WorkloadSpec::synth(30, 9, total_nodes)];
    m
}

#[test]
fn workload_merge_is_byte_identical() {
    let dir = scratch("workload");
    let matrix = synth_workload_matrix();
    let unsharded = dir.join("unsharded");
    std::fs::create_dir_all(&unsharded).expect("mkdir");
    let results = wsweep::run_workload_matrix(&matrix, THREADS).expect("unsharded workload");
    results.write(&unsharded, true).expect("unsharded write");

    let root = dir.join("sharded");
    for index in 1..=3 {
        let spec = ShardSpec { index, count: 3 };
        let report = shard::run_workload_shard(&matrix, spec, &root, true, THREADS)
            .unwrap_or_else(|e| panic!("workload shard {index}/3: {e:#}"));
        assert_eq!(report.outcome, ShardOutcome::Computed);
        assert_eq!(report.cells_total, matrix.len());
    }
    let report = shard::merge_run(&root).expect("workload merge");
    assert_eq!(report.kind, "workload");
    assert_eq!(report.cells, matrix.len());
    assert_same_files(&unsharded, &report.run_dir, &WORKLOAD_SINKS, "workload");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 3. Lifecycle: resumability and corrupt-shard refusal
// ---------------------------------------------------------------------------

#[test]
fn complete_shards_are_skipped_and_deleted_ones_recompute() {
    let dir = scratch("resume");
    let matrices = vec![mini_matrix()];
    let root = dir.join("out");
    let run = |index: usize| {
        shard::run_sweep_shard(
            &matrices,
            Engine::Analytic,
            ShardSpec { index, count: 3 },
            &root,
            true,
            THREADS,
        )
        .unwrap_or_else(|e| panic!("shard {index}/3: {e:#}"))
    };
    let mut shard2_dir = None;
    for index in 1..=3 {
        let r = run(index);
        assert_eq!(r.outcome, ShardOutcome::Computed, "first pass computes");
        if index == 2 {
            shard2_dir = Some(r.shard_dir);
        }
    }
    // Second pass: every shard's manifest validates, nothing recomputes.
    for index in 1..=3 {
        assert_eq!(run(index).outcome, ShardOutcome::Skipped, "complete shard re-runs");
    }
    // Delete one shard; only it recomputes.
    std::fs::remove_dir_all(shard2_dir.expect("shard 2 ran")).expect("delete shard 2");
    assert_eq!(run(1).outcome, ShardOutcome::Skipped);
    assert_eq!(run(2).outcome, ShardOutcome::Computed, "deleted shard recomputes");
    assert_eq!(run(3).outcome, ShardOutcome::Skipped);
    // And the healed run still merges byte-identically.
    let unsharded = dir.join("unsharded");
    run_unsharded_sweep(&matrices, Engine::Analytic, &unsharded);
    let report = shard::merge_run(&root).expect("merge after heal");
    assert_same_files(&unsharded, &report.run_dir, &SWEEP_SINKS, "healed run");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_shard_is_refused_by_merge_and_recomputed_on_rerun() {
    let dir = scratch("truncate");
    let matrices = vec![mini_matrix()];
    let root = dir.join("out");
    let mut part_path = None;
    for index in 1..=3 {
        let r = shard::run_sweep_shard(
            &matrices,
            Engine::Analytic,
            ShardSpec { index, count: 3 },
            &root,
            true,
            THREADS,
        )
        .expect("shard runs");
        if index == 2 {
            part_path = Some(r.shard_dir.join(shard::PART_FILE));
        }
    }
    let part_path = part_path.expect("shard 2 ran");
    let intact = std::fs::read(&part_path).expect("read part");
    std::fs::write(&part_path, &intact[..intact.len() / 2]).expect("truncate part");

    let err = shard::merge_run(&root).expect_err("merge must refuse a truncated shard");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("truncated") || msg.contains("checksum") || msg.contains("validation"),
        "error should name the corruption: {msg}"
    );
    // Resumability treats the damaged shard as incomplete and recomputes.
    let r = shard::run_sweep_shard(
        &matrices,
        Engine::Analytic,
        ShardSpec { index: 2, count: 3 },
        &root,
        true,
        THREADS,
    )
    .expect("re-run over damaged shard");
    assert_eq!(r.outcome, ShardOutcome::Computed, "damaged shard must recompute");
    assert!(shard::merge_run(&root).is_ok(), "merge succeeds after recomputation");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_sink_bytes_are_refused() {
    // Flip bytes in a shard's CSV sink (same length, different content):
    // the manifest checksum must catch it.
    let dir = scratch("bitrot");
    let matrices = vec![mini_matrix()];
    let root = dir.join("out");
    let r = shard::run_sweep_shard(
        &matrices,
        Engine::Analytic,
        ShardSpec { index: 1, count: 1 },
        &root,
        true,
        THREADS,
    )
    .expect("shard runs");
    let sink = r.shard_dir.join("sweep_summary.csv");
    let mut bytes = std::fs::read(&sink).expect("read sink");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&sink, &bytes).expect("corrupt sink");
    let err = shard::merge_run(&root).expect_err("merge must refuse corrupt sink bytes");
    assert!(format!("{err:#}").contains("checksum"), "unexpected error: {err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_shard_is_refused_with_its_index() {
    let dir = scratch("missing");
    let matrices = vec![mini_matrix()];
    let root = dir.join("out");
    let mut shard3_dir = None;
    for index in 1..=3 {
        let r = shard::run_sweep_shard(
            &matrices,
            Engine::Analytic,
            ShardSpec { index, count: 3 },
            &root,
            true,
            THREADS,
        )
        .expect("shard runs");
        if index == 3 {
            shard3_dir = Some(r.shard_dir);
        }
    }
    std::fs::remove_dir_all(shard3_dir.expect("shard 3 ran")).expect("delete shard 3");
    let err = shard::merge_run(&root).expect_err("merge must refuse an incomplete run");
    assert!(format!("{err:#}").contains("3/3"), "error should name the missing shard: {err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn merge_binary_exits_nonzero_on_corrupt_shard() {
    // The acceptance criterion end to end: a truncated shard file makes
    // the real `paraspawn merge` process exit non-zero.
    let dir = scratch("cli-corrupt");
    let matrices = vec![mini_matrix()];
    let root = dir.join("out");
    let mut part_path = None;
    for index in 1..=2 {
        let r = shard::run_sweep_shard(
            &matrices,
            Engine::Analytic,
            ShardSpec { index, count: 2 },
            &root,
            true,
            THREADS,
        )
        .expect("shard runs");
        if index == 1 {
            part_path = Some(r.shard_dir.join(shard::PART_FILE));
        }
    }
    let bin = env!("CARGO_BIN_EXE_paraspawn");
    // Sanity: the intact run merges with exit code 0.
    let ok = std::process::Command::new(bin)
        .arg("merge")
        .arg(&root)
        .output()
        .expect("spawning paraspawn merge");
    assert!(
        ok.status.success(),
        "intact merge should succeed: {}",
        String::from_utf8_lossy(&ok.stderr)
    );
    // Truncate a part file; the merge must now fail loudly.
    let part_path = part_path.expect("shard 1 ran");
    let intact = std::fs::read(&part_path).expect("read part");
    std::fs::write(&part_path, &intact[..intact.len() - 7]).expect("truncate");
    let bad = std::process::Command::new(bin)
        .arg("merge")
        .arg(&root)
        .output()
        .expect("spawning paraspawn merge");
    assert!(
        !bad.status.success(),
        "merge over a truncated shard must exit non-zero (stdout: {})",
        String::from_utf8_lossy(&bad.stdout)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shards_of_different_runs_do_not_collide() {
    // Different matrices hash to different run ids, so their shard
    // outputs land in different run directories under one --out root
    // (the coordination-free property), and each merges independently.
    let dir = scratch("two-runs");
    let root = dir.join("out");
    let a = vec![mini_matrix()];
    let b = vec![mini_matrix().seed(8)]; // one axis differs -> new run id
    let ra = shard::run_sweep_shard(&a, Engine::Analytic, ShardSpec { index: 1, count: 1 }, &root, true, THREADS)
        .expect("run a");
    let rb = shard::run_sweep_shard(&b, Engine::Analytic, ShardSpec { index: 1, count: 1 }, &root, true, THREADS)
        .expect("run b");
    assert_ne!(ra.run, rb.run, "distinct matrices must get distinct run ids");
    assert_ne!(ra.run_dir, rb.run_dir);
    assert!(shard::merge_run(&ra.run_dir).is_ok());
    assert!(shard::merge_run(&rb.run_dir).is_ok());
    // The shared root now holds two run dirs; a bare merge on the root
    // must refuse to guess between them.
    assert!(shard::merge_run(&root).is_err(), "ambiguous root must be refused");
    let _ = std::fs::remove_dir_all(&dir);
}
