//! Property-based tests over the coordinator invariants (planner math,
//! shrink decisions, redistribution plans, and end-to-end rank layout),
//! using the in-tree mini property-test framework.

use paraspawn::mam::plan::{
    diffusive_trace, hypercube_steps, plan_steps, Plan, SpawnTask,
};
use paraspawn::mam::shrink::decide;
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::redistrib::block_plan;
use paraspawn::testing::{check, Gen};
use std::collections::BTreeMap;

fn random_hypercube_plan(g: &mut Gen) -> Plan {
    let c = g.usize_in(1, 9) as u32;
    let total = g.usize_in(2, 40);
    let i = g.usize_in(1, total);
    let method = if g.bool() { Method::Merge } else { Method::Baseline };
    let mut r = vec![0u32; total];
    for ri in r.iter_mut().take(i) {
        *ri = c;
    }
    Plan::new(0, method, SpawnStrategy::ParallelHypercube, (0..total).collect(), vec![c; total], r)
}

fn random_diffusive_plan(g: &mut Gen) -> Plan {
    let total = g.usize_in(2, 30);
    let i = g.usize_in(1, total);
    let mut a = Vec::new();
    let mut r = vec![0u32; total];
    for idx in 0..total {
        a.push(g.usize_in(1, 16) as u32);
    }
    for idx in 0..i {
        // Sources partially or fully occupy their nodes.
        r[idx] = g.usize_in(1, a[idx] as usize + 1) as u32;
    }
    let method = if g.bool() { Method::Merge } else { Method::Baseline };
    Plan::new(0, method, SpawnStrategy::ParallelDiffusive, (0..total).collect(), a, r)
}

/// Flatten a plan's assignments to (slot, task) pairs.
fn all_tasks(plan: &Plan) -> Vec<(usize, SpawnTask)> {
    let mut out = Vec::new();
    for (slot, tasks) in plan.assignments() {
        for t in tasks {
            out.push((slot, t));
        }
    }
    out
}

#[test]
fn prop_every_group_spawned_exactly_once() {
    check("every group spawned exactly once", 200, |g| {
        let plan =
            if g.bool() { random_hypercube_plan(g) } else { random_diffusive_plan(g) };
        let mut gids: Vec<usize> = all_tasks(&plan).iter().map(|(_, t)| t.group.gid).collect();
        gids.sort_unstable();
        let expected: Vec<usize> = (0..plan.groups().len()).collect();
        if gids == expected {
            Ok(())
        } else {
            Err(format!("gids {gids:?} != 0..{}", plan.groups().len()))
        }
    });
}

#[test]
fn prop_spawned_totals_match_s_vector() {
    check("spawn totals match S", 200, |g| {
        let plan =
            if g.bool() { random_hypercube_plan(g) } else { random_diffusive_plan(g) };
        let total: usize =
            all_tasks(&plan).iter().map(|(_, t)| t.group.size as usize).sum();
        if total == plan.spawn_total() {
            Ok(())
        } else {
            Err(format!("{total} != {}", plan.spawn_total()))
        }
    });
}

#[test]
fn prop_spawner_existed_before_its_step() {
    // A slot can only spawn in step s if the process already exists:
    // slot < t_{s-1} (sources + groups spawned in earlier steps).
    check("spawners exist before their step", 200, |g| {
        let plan =
            if g.bool() { random_hypercube_plan(g) } else { random_diffusive_plan(g) };
        // Existing processes after each step: start with sources.
        let mut t_by_step = vec![plan.ns()];
        let mut by_step: BTreeMap<usize, Vec<SpawnTask>> = BTreeMap::new();
        for (_, t) in all_tasks(&plan) {
            by_step.entry(t.step).or_default().push(t);
        }
        for (step, tasks) in &by_step {
            let available = *t_by_step.last().unwrap();
            let grown: usize = tasks.iter().map(|t| t.group.size as usize).sum();
            while t_by_step.len() <= *step {
                t_by_step.push(available);
            }
            t_by_step[*step] = available + grown;
        }
        for (slot, task) in all_tasks(&plan) {
            let available = t_by_step[task.step - 1];
            if slot >= available {
                return Err(format!(
                    "slot {slot} spawns in step {} but only {available} procs exist",
                    task.step
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hypercube_steps_match_eq3() {
    check("hypercube step count == Eq. 3", 200, |g| {
        let c = g.usize_in(1, 9) as u32;
        let total = g.usize_in(2, 60);
        let i = g.usize_in(1, total);
        let mut r = vec![0u32; total];
        for ri in r.iter_mut().take(i) {
            *ri = c;
        }
        let plan = Plan::new(
            0,
            Method::Merge,
            SpawnStrategy::ParallelHypercube,
            (0..total).collect(),
            vec![c; total],
            r,
        );
        let got = plan_steps(&plan);
        let want = hypercube_steps(c, i, total);
        if got == want {
            Ok(())
        } else {
            Err(format!("C={c} I={i} N={total}: steps {got} != Eq3 {want}"))
        }
    });
}

#[test]
fn prop_diffusive_trace_recurrences() {
    check("diffusive trace satisfies Eq. 4-8", 200, |g| {
        let plan = random_diffusive_plan(g);
        let rows = diffusive_trace(&plan);
        // Eq. 4: t_s = t_{s-1} + g_s; Eq. 6: lambda_s = lambda_{s-1} + t_{s-1};
        // Eq. 7: T_s = T_{s-1} + G_s; final coverage: lambda >= N.
        for w in rows.windows(2) {
            let (p, c) = (w[0], w[1]);
            if c.t != p.t + c.g {
                return Err(format!("Eq4 violated at s={}", c.s));
            }
            if c.lambda != p.lambda + p.t {
                return Err(format!("Eq6 violated at s={}", c.s));
            }
            if c.tt != p.tt + c.gg {
                return Err(format!("Eq7 violated at s={}", c.s));
            }
        }
        let last = rows.last().unwrap();
        if last.lambda < plan.n_nodes() {
            return Err("S not fully consumed".into());
        }
        if last.t != plan.ns() + plan.spawn_total() {
            return Err(format!("final t {} != NS+spawned", last.t));
        }
        Ok(())
    });
}

#[test]
fn prop_shrink_decision_partitions_ranks() {
    check("shrink decision partitions ranks", 300, |g| {
        let n_nodes = g.usize_in(1, 8);
        let per_node = g.usize_in(1, 5);
        let mut nodes = Vec::new();
        let mut mcws = Vec::new();
        // Random MCW structure: contiguous chunks across the rank space.
        let mut mcw_id = 0u64;
        for node in 0..n_nodes {
            for k in 0..per_node {
                nodes.push(node);
                if k == 0 && g.bool() {
                    mcw_id += 1;
                }
                mcws.push(mcw_id);
            }
        }
        let mut target = BTreeMap::new();
        for node in 0..n_nodes {
            let keep = g.usize_in(0, per_node + 1) as u32;
            if keep > 0 {
                target.insert(node, keep);
            }
        }
        let d = decide(&nodes, &mcws, &target);
        let total = d.survivors.len() + d.terminate.len() + d.zombies.len();
        if total != nodes.len() {
            return Err(format!("partition broken: {total} != {}", nodes.len()));
        }
        // Quota respected per node.
        for node in 0..n_nodes {
            let kept = d.survivors.iter().filter(|&&r| nodes[r] == node).count() as u32;
            let quota = target.get(&node).copied().unwrap_or(0);
            let present = nodes.iter().filter(|&&x| x == node).count() as u32;
            if kept != quota.min(present) {
                return Err(format!("node {node}: kept {kept}, quota {quota}"));
            }
        }
        // Released nodes host no survivors and no zombies.
        for &node in &d.released_nodes {
            if d.survivors.iter().chain(&d.zombies).any(|&r| nodes[r] == node) {
                return Err(format!("released node {node} still occupied"));
            }
        }
        // Zombies only in partially-surviving MCWs.
        for &z in &d.zombies {
            let members: Vec<usize> =
                (0..nodes.len()).filter(|&r| mcws[r] == mcws[z]).collect();
            if members.iter().all(|r| !d.survivors.contains(r)) {
                // whole MCW is victim and within... then it should be TS
                // unless some member is a zombie forced by another node?
                return Err(format!("zombie {z} in fully-victim MCW"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_block_plan_conserves_and_covers() {
    check("block plan conserves bytes and covers targets", 300, |g| {
        let ns = g.usize_in(1, 33);
        let nt = g.usize_in(1, 33);
        let total = g.u64_below(1 << 30);
        let plan = block_plan(ns, nt, total);
        let sum: u64 = plan.iter().map(|t| t.bytes).sum();
        if sum != total {
            return Err(format!("bytes {sum} != {total}"));
        }
        let b = total as u128;
        for j in 0..nt {
            let need = (b * (j as u128 + 1) / nt as u128 - b * j as u128 / nt as u128) as u64;
            let got: u64 = plan.iter().filter(|t| t.dst == j).map(|t| t.bytes).sum();
            if got != need {
                return Err(format!("target {j}: {got} != {need}"));
            }
        }
        if plan.iter().any(|t| t.src >= ns || t.dst >= nt) {
            return Err("rank out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mann_whitney_orders_shifted_samples() {
    check("mann-whitney detects large shifts", 60, |g| {
        let mut rng = paraspawn::util::rng::Rng::new(g.u64_below(u64::MAX - 1));
        let n = g.usize_in(15, 40);
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal() + 5.0).collect();
        let r = paraspawn::util::stats::mann_whitney_u(&a, &b);
        if r.p_value < 0.01 {
            Ok(())
        } else {
            Err(format!("p = {} for a 5-sigma shift", r.p_value))
        }
    });
}

/// End-to-end property: after a random expansion the final rank layout
/// matches the plan (sources keep low ranks — Merge — then spawned groups
/// in group-id order, each contiguous on its node) — the §4.5 reordering
/// invariant, for every strategy and both methods.
#[test]
fn prop_end_to_end_rank_layout_matches_plan() {
    use paraspawn::app::{run_malleable, AppSpec, ResizeEvent};
    use paraspawn::config::{CostModel, SimConfig};
    use paraspawn::rms::Allocation;
    use paraspawn::simmpi::World;
    use paraspawn::topology::Cluster;
    use std::sync::Arc;

    check("end-to-end rank layout matches the plan", 12, |g| {
        let n_nodes = g.usize_in(2, 5);
        let cores = g.usize_in(1, 4) as u32;
        let i_nodes = g.usize_in(1, n_nodes);
        let strategy = g.pick(&[
            SpawnStrategy::ParallelHypercube,
            SpawnStrategy::ParallelDiffusive,
            SpawnStrategy::NodeByNode,
            SpawnStrategy::Plain,
            SpawnStrategy::Single,
        ]);
        let method = if g.bool() { Method::Merge } else { Method::Baseline };
        if i_nodes == n_nodes {
            return Ok(()); // nothing to expand
        }
        let cluster = Cluster::mini(n_nodes, cores);
        let initial = Allocation::new((0..i_nodes).map(|n| (n, cores)).collect());
        let target = Allocation::new((0..n_nodes).map(|n| (n, cores)).collect());

        let world = World::new(
            cluster,
            SimConfig { cost: CostModel::mn5().deterministic(), ..Default::default() }
                .seeded(g.u64_below(1 << 40)),
        );
        let spec = Arc::new(AppSpec {
            iters_per_epoch: 1,
            work_per_iter: 1.0,
            points_per_iter: 0,
            trace: vec![ResizeEvent::new(target, method, strategy)],
            data_bytes: 0,
            ..Default::default()
        });
        run_malleable(&world, &initial, spec).map_err(|e| e.to_string())?;

        let recs = world.metrics.reconfigs();
        if recs.len() != 1 {
            return Err(format!("expected 1 record, got {}", recs.len()));
        }
        let rec = &recs[0];
        if rec.ns != i_nodes * cores as usize || rec.nt != n_nodes * cores as usize {
            return Err(format!("ns/nt mismatch: {}/{}", rec.ns, rec.nt));
        }

        // Expected layout: (Merge) sources node-major first, then spawned
        // groups by gid; (Baseline) the whole new set node-major.
        let mut expected: Vec<usize> = Vec::new();
        if method == Method::Merge {
            for node in 0..i_nodes {
                expected.extend(std::iter::repeat(node).take(cores as usize));
            }
            for node in i_nodes..n_nodes {
                expected.extend(std::iter::repeat(node).take(cores as usize));
            }
        } else {
            for node in 0..n_nodes {
                expected.extend(std::iter::repeat(node).take(cores as usize));
            }
        }
        let layouts = world.metrics.layouts();
        let (_, layout) = layouts.first().ok_or("no layout recorded")?;
        if *layout != expected {
            return Err(format!(
                "{method:?}+{strategy:?} {i_nodes}->{n_nodes}x{cores}: layout {layout:?} != expected {expected:?}"
            ));
        }
        Ok(())
    });
}
