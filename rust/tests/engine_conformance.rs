//! Differential conformance: the closed-form analytic engine
//! (`mam::model`) vs the thread-per-rank simulator.
//!
//! Under a deterministic cost model every charge in the simulator is a
//! fixed f64 expression, so the analytic engine must reproduce totals
//! AND per-phase breakdowns **bit-exactly** (`f64::to_bits` equality,
//! not epsilon closeness). The property sweeps below generate random
//! scenarios across strategy × method × direction × cluster shape and
//! compare the two engines end to end — well over 256 cases per run.
//!
//! Under stochastic cost models the analytic engine returns the
//! jitter-free location parameters; the invariant checks pin down the
//! structural properties that must hold regardless of dispersion.

use paraspawn::config::CostModel;
use paraspawn::coordinator::{
    run_reconfiguration, run_reconfiguration_analytic, ReconfigReport, Scenario,
};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::AllocPolicy;
use paraspawn::testing::{check, Gen};
use paraspawn::topology::{Cluster, LinkKind, NodeSpec, Switch};

/// A small two-type, two-switch cluster in the NASP shape (IB small
/// nodes + Ethernet big nodes sharing an uplink), sized for fast
/// thread-simulated property cases.
fn mini_hetero(small: usize, small_cores: u32, big: usize, big_cores: u32) -> Cluster {
    let switches = vec![
        Switch { name: "mh-ib".into(), fabric: LinkKind::InfiniBand100 },
        Switch { name: "mh-eth".into(), fabric: LinkKind::Ethernet10 },
    ];
    let mut nodes = Vec::new();
    for i in 0..small {
        nodes.push(NodeSpec { name: format!("mh-a{i}"), cores: small_cores, switch: 0 });
    }
    for i in 0..big {
        nodes.push(NodeSpec { name: format!("mh-b{i}"), cores: big_cores, switch: 1 });
    }
    Cluster { name: "mini-hetero".into(), nodes, switches, inter_switch: LinkKind::Ethernet10 }
}

/// Bit-exact comparison of two reports; returns a description of the
/// first divergence.
fn compare(sim: &ReconfigReport, ana: &ReconfigReport) -> Result<(), String> {
    if sim.total_time.to_bits() != ana.total_time.to_bits() {
        return Err(format!(
            "total mismatch: simulated {} ({:#x}) vs analytic {} ({:#x})",
            sim.total_time,
            sim.total_time.to_bits(),
            ana.total_time,
            ana.total_time.to_bits()
        ));
    }
    if sim.strategy_label != ana.strategy_label {
        return Err(format!("label mismatch: {} vs {}", sim.strategy_label, ana.strategy_label));
    }
    if (sim.ns, sim.nt) != (ana.ns, ana.nt) {
        return Err(format!(
            "NS/NT mismatch: ({}, {}) vs ({}, {})",
            sim.ns, sim.nt, ana.ns, ana.nt
        ));
    }
    if sim.phases.len() != ana.phases.len() {
        return Err(format!(
            "phase count mismatch: {:?} vs {:?}",
            sim.phases, ana.phases
        ));
    }
    for ((ps, ds), (pa, da)) in sim.phases.iter().zip(&ana.phases) {
        if ps != pa || ds.to_bits() != da.to_bits() {
            return Err(format!(
                "phase mismatch at {}: simulated ({}, {}) vs analytic ({}, {})\n  sim: {:?}\n  ana: {:?}",
                ps.name(),
                ps.name(),
                ds,
                pa.name(),
                da,
                sim.phases,
                ana.phases
            ));
        }
    }
    if sim.nodes_returned != ana.nodes_returned {
        return Err(format!(
            "nodes_returned mismatch: {} vs {}",
            sim.nodes_returned, ana.nodes_returned
        ));
    }
    if sim.zombies != ana.zombies {
        return Err(format!("zombies mismatch: {} vs {}", sim.zombies, ana.zombies));
    }
    Ok(())
}

fn run_both(s: &Scenario) -> Result<(), String> {
    let sim = run_reconfiguration(s).map_err(|e| format!("simulated failed: {e:#}"))?;
    let ana = run_reconfiguration_analytic(s).map_err(|e| format!("analytic failed: {e:#}"))?;
    compare(&sim, &ana).map_err(|msg| {
        format!(
            "{} {}+{} {}->{} data={} on {}: {}",
            if s.target_nodes < s.initial_nodes { "shrink" } else { "expand" },
            s.method.name(),
            s.strategy.name(),
            s.initial_nodes,
            s.target_nodes,
            s.data_bytes,
            s.cluster.name,
            msg
        )
    })
}

/// Random homogeneous-cluster scenario (all five strategies are legal).
fn homogeneous_scenario(g: &mut Gen) -> Scenario {
    let nodes = g.usize_in(2, 7);
    let cores = g.usize_in(1, 5) as u32;
    let cluster = Cluster::mini(nodes, cores);
    let strategy = g.pick(&[
        SpawnStrategy::Plain,
        SpawnStrategy::Single,
        SpawnStrategy::NodeByNode,
        SpawnStrategy::ParallelHypercube,
        SpawnStrategy::ParallelDiffusive,
    ]);
    let method = g.pick(&[Method::Merge, Method::Baseline]);
    let mut i = g.usize_in(1, nodes + 1);
    let mut n = g.usize_in(1, nodes + 1);
    if i == n {
        n = if n == nodes { 1 } else { n + 1 };
    }
    // Merge shrinks take the TS/ZS path regardless of strategy; keep the
    // strategy axis meaningful by only shrinking via Merge occasionally.
    if n < i && method == Method::Merge && !g.bool() {
        std::mem::swap(&mut i, &mut n);
    }
    let data_bytes = match g.usize_in(0, 3) {
        0 => 0,
        1 => g.usize_in(1, 4096) as u64,
        // Above the eager limit: exercises the rendezvous sender path.
        _ => g.usize_in(60_000, 300_000) as u64,
    };
    Scenario {
        cluster,
        cost: CostModel::mn5().deterministic(),
        policy: AllocPolicy::WholeNodes,
        initial_nodes: i,
        target_nodes: n,
        method,
        strategy,
        seed: g.u64_below(1 << 20),
        warmup_iters: g.usize_in(0, 3),
        data_bytes,
        prepare_parallel: n < i,
    }
}

/// Random heterogeneous-cluster scenario (Hypercube excluded, as on
/// NASP; balanced-type allocations).
fn heterogeneous_scenario(g: &mut Gen) -> Scenario {
    let small = g.usize_in(2, 4);
    let big = g.usize_in(2, 4);
    let small_cores = g.usize_in(1, 3) as u32;
    let big_cores = small_cores + g.usize_in(1, 3) as u32;
    let cluster = mini_hetero(small, small_cores, big, big_cores);
    let max_nodes = small.min(big) * 2;
    let strategy = g.pick(&[
        SpawnStrategy::Plain,
        SpawnStrategy::Single,
        SpawnStrategy::NodeByNode,
        SpawnStrategy::ParallelDiffusive,
    ]);
    let method = g.pick(&[Method::Merge, Method::Baseline]);
    let mut i = g.usize_in(1, max_nodes + 1);
    let mut n = g.usize_in(1, max_nodes + 1);
    if i == n {
        n = if n == max_nodes { 1 } else { n + 1 };
    }
    if n < i && method == Method::Merge && !g.bool() {
        std::mem::swap(&mut i, &mut n);
    }
    Scenario {
        cluster,
        cost: CostModel::nasp().deterministic(),
        policy: AllocPolicy::BalancedTypes,
        initial_nodes: i,
        target_nodes: n,
        method,
        strategy,
        seed: g.u64_below(1 << 20),
        warmup_iters: g.usize_in(0, 2),
        data_bytes: if g.bool() { 0 } else { g.usize_in(1, 100_000) as u64 },
        prepare_parallel: n < i,
    }
}

#[test]
fn analytic_matches_simulator_bit_exactly_homogeneous() {
    check("analytic == simulated (homogeneous)", 192, |g| {
        run_both(&homogeneous_scenario(g))
    });
}

#[test]
fn analytic_matches_simulator_bit_exactly_heterogeneous() {
    check("analytic == simulated (heterogeneous)", 96, |g| {
        run_both(&heterogeneous_scenario(g))
    });
}

/// Directed coverage of every strategy × method × direction cell on one
/// fixed cluster shape (the property sweeps randomize around these).
#[test]
fn analytic_matches_simulator_all_config_cells() {
    let strategies = [
        SpawnStrategy::Plain,
        SpawnStrategy::Single,
        SpawnStrategy::NodeByNode,
        SpawnStrategy::ParallelHypercube,
        SpawnStrategy::ParallelDiffusive,
    ];
    for &strategy in &strategies {
        for &method in &[Method::Merge, Method::Baseline] {
            for &(i, n) in &[(1usize, 4usize), (2, 4), (4, 2), (4, 1)] {
                let s = Scenario {
                    cluster: Cluster::mini(4, 3),
                    cost: CostModel::mn5().deterministic(),
                    policy: AllocPolicy::WholeNodes,
                    initial_nodes: i,
                    target_nodes: n,
                    method,
                    strategy,
                    seed: 7,
                    warmup_iters: 1,
                    data_bytes: 2048,
                    prepare_parallel: n < i,
                };
                if let Err(msg) = run_both(&s) {
                    panic!("cell {}+{} {}->{}: {}", method.name(), strategy.name(), i, n, msg);
                }
            }
        }
    }
}

/// Stochastic-model invariants: the analytic engine reports location
/// parameters plus structural guarantees that hold for any dispersion.
#[test]
fn stochastic_invariants_hold() {
    check("stochastic invariants", 64, |g| {
        let mut s = homogeneous_scenario(g);
        s.cost = CostModel::mn5(); // jitter_frac > 0
        let ana = run_reconfiguration_analytic(&s)
            .map_err(|e| format!("analytic failed: {e:#}"))?;
        // Phase durations are non-negative and partition at most the
        // total (the lap clock is monotone; trailing teardown may extend
        // t_end past the last lap).
        for (p, d) in &ana.phases {
            if *d < 0.0 {
                return Err(format!("negative {} phase: {}", p.name(), d));
            }
        }
        let sum: f64 = ana.phases.iter().map(|(_, d)| d).sum();
        if sum > ana.total_time + 1e-9 {
            return Err(format!("phase sum {} exceeds total {}", sum, ana.total_time));
        }
        // Monotone in the redistribution payload.
        let mut bigger = s.clone();
        bigger.data_bytes = s.data_bytes + (1 << 20);
        let ana_big = run_reconfiguration_analytic(&bigger)
            .map_err(|e| format!("analytic failed: {e:#}"))?;
        if ana_big.total_time < ana.total_time {
            return Err(format!(
                "payload monotonicity violated: {} B -> {}, {} B -> {}",
                s.data_bytes, ana.total_time, bigger.data_bytes, ana_big.total_time
            ));
        }
        // The analytic location equals the deterministic-model timing:
        // dispersion never shifts the reported parameters.
        let mut det = s.clone();
        det.cost = det.cost.deterministic();
        let ana_det = run_reconfiguration_analytic(&det)
            .map_err(|e| format!("analytic failed: {e:#}"))?;
        if ana.total_time.to_bits() != ana_det.total_time.to_bits() {
            return Err("stochastic-model analytic result drifted from the location".into());
        }
        // And a sampled simulated run stays in a generous envelope
        // around the location (3% per-charge lognormal jitter cannot
        // halve or double an aggregate resize time).
        let sim = run_reconfiguration(&s).map_err(|e| format!("simulated failed: {e:#}"))?;
        let ratio = sim.total_time / ana.total_time;
        if !(0.5..=2.0).contains(&ratio) {
            return Err(format!(
                "sampled total {} implausibly far from location {} (ratio {})",
                sim.total_time, ana.total_time, ratio
            ));
        }
        Ok(())
    });
}
