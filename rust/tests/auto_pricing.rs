//! Acceptance tests for per-resize strategy autotuning
//! ([`paraspawn::selector`] + [`paraspawn::rms::sched::AutoPricer`] —
//! the `--pricing auto` arm).
//!
//! Three claims are pinned:
//!
//! 1. **Dominance**: on both bundled traces the auto arm's total
//!    reconfiguration node-seconds never exceed the cheaper of the two
//!    fixed stateful arms — the grid it argmins over contains both
//!    arms' per-event choices, priced in the same cluster state.
//! 2. **The Forced escape hatch**: an `AutoPricer` forced everywhere to
//!    a fixed (strategy, method) pair is bit-identical in
//!    `SchedResult` to the corresponding fixed stateful arm, down to
//!    the empty decision column.
//! 3. **Determinism**: `--pricing auto` workloads are bit-identical
//!    across thread counts, like every other arm.

use paraspawn::config::CostModel;
use paraspawn::coordinator::sweep::ClusterKind;
use paraspawn::coordinator::wsweep::{
    auto_pricers, kind_cost_model, run_workload_matrix, stateful_pricers, WorkloadMatrix,
    WorkloadSpec,
};
use paraspawn::mam::Method;
use paraspawn::rms::sched::{
    self, schedule_with_pricer, AnalyticPricer, AutoPricer, ResizePricer, SchedPolicy,
    StatefulPricer,
};
use paraspawn::rms::workload::JobSpec;
use paraspawn::rms::AllocPolicy;
use paraspawn::topology::Cluster;
use std::path::PathBuf;

/// A bundled SWF trace with the canonical malleability overlay (the
/// same parameters the replay example and the stateful acceptance
/// tests use).
fn trace_jobs(name: &str, total_nodes: usize, cores: u32) -> Vec<JobSpec> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data").join(name);
    let text = std::fs::read_to_string(&path).expect("bundled trace readable");
    let mut jobs = sched::read_swf(&text, cores, total_nodes).expect("bundled trace parses");
    sched::mark_malleable(&mut jobs, 0.7, 4, total_nodes, 2025);
    jobs
}

/// Run the malleable policy under TS-state, SS-state and auto on one
/// trace and assert the auto arm never pays more reconfiguration
/// node-seconds than the cheaper fixed arm.
fn assert_auto_dominates(kind: ClusterKind, trace: &str) {
    let cluster = kind.cluster();
    let cores = cluster.nodes.iter().map(|n| n.cores).min().unwrap_or(1);
    let jobs = trace_jobs(trace, cluster.len(), cores);
    assert!(jobs.len() >= 50, "bundled trace must stay non-trivial ({})", jobs.len());
    let cost = kind_cost_model(kind);
    let mut pricers = stateful_pricers(&cost, None, 0);
    pricers.extend(auto_pricers(&cost, 0));
    let matrix = WorkloadMatrix {
        pricers,
        policies: vec![SchedPolicy::Malleable],
        workloads: vec![WorkloadSpec::new(trace, jobs)],
        ..WorkloadMatrix::for_kind(kind)
    };
    let r = run_workload_matrix(&matrix, 2).unwrap();
    let get = |arm: &str| {
        r.cells[&(trace.to_string(), "malleable".to_string(), arm.to_string())].clone()
    };
    let auto = get("auto");
    let ts = get("TS-state");
    let ss = get("SS-state");
    assert!(auto.reconfigurations() > 0, "{trace}: the auto arm never reconfigured");
    let best = ts.reconfig_node_seconds.min(ss.reconfig_node_seconds);
    assert!(
        auto.reconfig_node_seconds <= best,
        "{trace}: auto reconfig node-seconds {} exceed the best fixed stateful arm {}",
        auto.reconfig_node_seconds,
        best
    );
    // The per-event winners actually land in the decision column, and
    // only there — fixed arms stay empty.
    assert!(
        auto.decisions.iter().any(|d| !d.is_empty()),
        "{trace}: the auto arm recorded no decisions"
    );
    assert!(
        auto.decisions.iter().flat_map(|d| d.split(';')).all(|t| {
            t.is_empty() || t.starts_with("e:") || t.starts_with("s:")
        }),
        "{trace}: malformed decision tokens: {:?}",
        auto.decisions
    );
    assert!(
        ts.decisions.iter().chain(&ss.decisions).all(|d| d.is_empty()),
        "{trace}: fixed arms must keep an empty decision column"
    );
}

#[test]
fn auto_never_pays_more_than_fixed_stateful_arms_smoke() {
    assert_auto_dominates(ClusterKind::Mini, "replay_smoke.swf");
}

#[test]
fn auto_never_pays_more_than_fixed_stateful_arms_replay2k() {
    assert_auto_dominates(ClusterKind::Mn5, "replay2k.swf");
}

/// The Forced escape hatch reproduces a fixed arm exactly: forcing
/// (widest strategy, Merge) everywhere must schedule bit-identically to
/// `StatefulPricer::ts`, and (widest strategy, Baseline) to
/// `StatefulPricer::ss` — same trajectory, same prices, same (empty)
/// decision column.
#[test]
fn forced_auto_is_bit_identical_to_the_fixed_stateful_arm() {
    let cluster = Cluster::mini(8, 4);
    let cost = CostModel::mn5();
    let jobs = trace_jobs("replay_smoke.swf", cluster.len(), 4);
    let strategy = AnalyticPricer::auto_strategy(&cluster);

    let run = |pricer: &mut dyn ResizePricer| {
        schedule_with_pricer(
            &cluster,
            AllocPolicy::WholeNodes,
            SchedPolicy::Malleable,
            pricer,
            &jobs,
        )
        .unwrap()
    };

    for (method, label) in [(Method::Merge, "TS-state"), (Method::Baseline, "SS-state")] {
        let mut forced = AutoPricer::forced(cluster.clone(), cost.clone(), strategy, method, 0);
        let mut fixed: Box<dyn ResizePricer> = match method {
            Method::Merge => Box::new(StatefulPricer::ts(cluster.clone(), cost.clone())),
            Method::Baseline => Box::new(StatefulPricer::ss(cluster.clone(), cost.clone())),
        };
        let f = run(&mut forced);
        let x = run(fixed.as_mut());
        assert!(f.reconfigurations() > 0, "{label}: the forced run never reconfigured");
        assert_eq!(f, x, "forced auto must reproduce {label} bit-exactly");
        assert!(
            f.decisions.iter().all(|d| d.is_empty()),
            "{label}: forced runs must record no online decisions"
        );
    }
}

/// `--pricing auto` is bit-identical across thread counts: the decision
/// memo iterates in deterministic order, every cell builds its own
/// pricer, and cells are reassembled in task order.
#[test]
fn auto_workload_is_bit_identical_across_thread_counts() {
    let kind = ClusterKind::Mini;
    let cluster = kind.cluster();
    let jobs = trace_jobs("replay_smoke.swf", cluster.len(), 4);
    let matrix = WorkloadMatrix {
        pricers: auto_pricers(&kind_cost_model(kind), 0),
        policies: vec![SchedPolicy::Fcfs, SchedPolicy::Malleable],
        workloads: vec![WorkloadSpec::new("smoke", jobs)],
        ..WorkloadMatrix::for_kind(kind)
    };
    let serial = run_workload_matrix(&matrix, 1).unwrap();
    let parallel = run_workload_matrix(&matrix, 4).unwrap();
    assert_eq!(serial, parallel, "auto cells must not depend on thread count");
    for ((_, policy, pricing), cell) in &serial.cells {
        if policy == "malleable" {
            assert!(cell.reconfigurations() > 0, "{pricing}: no reconfigurations");
        }
    }
}
