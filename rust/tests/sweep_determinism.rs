//! Determinism guarantees of the sweep engine and the simulator:
//!
//! * a sweep's results are byte-identical for `--threads 1` vs
//!   `--threads 8` (same seeds; repetitions are order-normalized by the
//!   executor);
//! * `run_reconfiguration` with a fixed seed is bit-reproducible across
//!   runs, jitter included (RNG streams derive by lineage, RTE
//!   contention by plan-derived queue positions — not wall-clock order).

use paraspawn::coordinator::sweep::{
    cell_scenario, mn5_shrink_configs, run_matrix, ClusterKind, MethodConfig, ScenarioMatrix,
};
use paraspawn::coordinator::{run_reconfiguration, run_samples};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::testing::{check, Gen};

fn mini_configs() -> Vec<MethodConfig> {
    use SpawnStrategy::*;
    vec![
        MethodConfig { label: "M", method: Method::Merge, strategy: Plain },
        MethodConfig { label: "M+HC", method: Method::Merge, strategy: ParallelHypercube },
        MethodConfig { label: "M+ID", method: Method::Merge, strategy: ParallelDiffusive },
        MethodConfig { label: "B+HC", method: Method::Baseline, strategy: ParallelHypercube },
    ]
}

/// Bit-level equality for sample maps (plain `==` would accept -0.0/0.0
/// confusion; the acceptance bar is *byte* identity).
fn assert_bit_identical(
    a: &paraspawn::coordinator::sweep::SweepResults,
    b: &paraspawn::coordinator::sweep::SweepResults,
) {
    assert_eq!(a.samples.len(), b.samples.len());
    for ((ka, xs), (kb, ys)) in a.samples.iter().zip(b.samples.iter()) {
        assert_eq!(ka, kb);
        assert_eq!(xs.len(), ys.len(), "{ka:?}");
        for (x, y) in xs.iter().zip(ys) {
            assert_eq!(x.to_bits(), y.to_bits(), "cell {ka:?}: {x} vs {y}");
        }
    }
    assert_eq!(a.phase_means, b.phase_means);
}

#[test]
fn sweep_results_identical_for_1_and_8_threads() {
    // Expansion cells across every strategy family on the mini cluster,
    // jitter ON (the MN5 cost model's 3%): determinism must not depend on
    // the deterministic() escape hatch.
    let matrix = ScenarioMatrix::new()
        .clusters(vec![ClusterKind::Mini])
        .configs(mini_configs())
        .pairs(vec![(1, 4), (2, 8), (1, 8)])
        .reps(3)
        .seed(0xDE7E);
    let serial = run_matrix(&matrix, 1).expect("serial sweep");
    let parallel = run_matrix(&matrix, 8).expect("parallel sweep");
    assert_eq!(serial.total_samples(), 3 * 4 * 3);
    assert_bit_identical(&serial, &parallel);
}

#[test]
fn shrink_sweep_identical_for_1_and_8_threads() {
    // Shrinks run the prepare-expansion + TS/SS paths.
    let matrix = ScenarioMatrix::new()
        .clusters(vec![ClusterKind::Mini])
        .configs(mn5_shrink_configs())
        .pairs(vec![(4, 1), (8, 2)])
        .reps(2)
        .seed(0x5EED);
    let serial = run_matrix(&matrix, 1).expect("serial sweep");
    let parallel = run_matrix(&matrix, 8).expect("parallel sweep");
    assert_bit_identical(&serial, &parallel);
}

#[test]
fn run_reconfiguration_is_reproducible_per_seed() {
    // Property: for random mini-cluster cells (any config, both
    // directions), two runs of the same seeded scenario agree bit-for-bit
    // on time, phases and side-effect counts — and a different seed with
    // jitter on produces a different total.
    check("run_reconfiguration reproducible", 8, |g: &mut Gen| {
        let configs = mini_configs();
        let mc = configs[g.usize_in(0, configs.len())];
        let (i, n) = g.pick(&[(1usize, 4usize), (2, 6), (4, 2), (8, 3)]);
        if n < i && mc.method == Method::Merge && mc.strategy != SpawnStrategy::Plain {
            // Merge shrinks ignore the strategy; normalize like fig4b.
            return Ok(());
        }
        let seed = g.u64_below(1 << 48);
        let s = cell_scenario(ClusterKind::Mini, i, n, &mc, seed);
        let a = run_reconfiguration(&s).map_err(|e| format!("{e:#}"))?;
        let b = run_reconfiguration(&s).map_err(|e| format!("{e:#}"))?;
        if a.total_time.to_bits() != b.total_time.to_bits() {
            return Err(format!("total {} vs {}", a.total_time, b.total_time));
        }
        if a.phases != b.phases {
            return Err(format!("phases {:?} vs {:?}", a.phases, b.phases));
        }
        if (a.ns, a.nt, a.nodes_returned, a.zombies) != (b.ns, b.nt, b.nodes_returned, b.zombies)
        {
            return Err("side-effect counters differ".into());
        }
        let c = run_reconfiguration(&s.clone().seeded(seed ^ 0xFFFF)).map_err(|e| e.to_string())?;
        if c.total_time.to_bits() == a.total_time.to_bits() {
            return Err("different seeds produced identical totals (jitter dead?)".into());
        }
        Ok(())
    });
}

#[test]
fn run_samples_is_rep_ordered_and_thread_invariant() {
    use paraspawn::coordinator::sweep::run_scenario_samples;
    let s = cell_scenario(
        ClusterKind::Mini,
        1,
        4,
        &MethodConfig {
            label: "M+HC",
            method: Method::Merge,
            strategy: SpawnStrategy::ParallelHypercube,
        },
        42,
    );
    let via_api = run_samples(&s, 4).unwrap();
    let serial = run_scenario_samples(&s, 4, 1).unwrap();
    let wide = run_scenario_samples(&s, 4, 8).unwrap();
    assert_eq!(via_api.len(), 4);
    for ((a, b), c) in via_api.iter().zip(&serial).zip(&wide) {
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(a.to_bits(), c.to_bits());
    }
    // Different reps use different derived seeds, so samples differ.
    assert!(via_api.windows(2).any(|w| w[0] != w[1]));
}
