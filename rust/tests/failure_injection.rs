//! Failure injection: the simulator must fail *loudly and promptly* on
//! protocol errors (panics, deadlocks, mismatched collectives), never
//! hang, and RMS/plan validation must reject inconsistent inputs.

use paraspawn::config::{CostModel, SimConfig};
use paraspawn::coordinator::{run_reconfiguration, Scenario};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::{AllocPolicy, Rms};
use paraspawn::simmpi::{Comm, Ctx, Payload, World};
use paraspawn::topology::Cluster;
use std::sync::Arc;
use std::time::Instant;

fn fast_watchdog() -> SimConfig {
    SimConfig {
        cost: CostModel::mn5().deterministic(),
        watchdog_secs: Some(1.5),
        ..Default::default()
    }
}

#[test]
fn mid_protocol_panic_unblocks_collective_peers() {
    let world = World::new(Cluster::mini(1, 4), fast_watchdog());
    world.launch(
        &[(0, 4)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 3 {
                panic!("injected failure before barrier");
            }
            ctx.barrier(&w); // would deadlock without abort propagation
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("injected failure"));
    assert!(t0.elapsed().as_secs_f64() < 5.0, "abort must release peers promptly");
}

#[test]
fn connect_to_unpublished_service_hits_watchdog() {
    let world = World::new(Cluster::mini(1, 1), fast_watchdog());
    world.launch(
        &[(0, 1)],
        Arc::new(|ctx: Ctx, _w: Comm| {
            let _ = ctx.lookup_name("service-that-never-exists");
        }),
    );
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("watchdog"));
}

#[test]
fn mismatched_collective_participation_aborts() {
    // Rank 0 calls barrier twice, rank 1 once, on a 2-rank comm: the
    // second instance can never complete -> watchdog.
    let world = World::new(Cluster::mini(1, 2), fast_watchdog());
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            ctx.barrier(&w);
            if w.rank() == 0 {
                ctx.barrier(&w);
            }
        }),
    );
    assert!(world.join_all().is_err());
}

#[test]
fn wrong_payload_type_panics_cleanly() {
    let world = World::new(Cluster::mini(1, 2), fast_watchdog());
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 0 {
                ctx.send(&w, 1, 1, Payload::Str("not ints".into()));
            } else {
                let (p, _, _) = ctx.recv(&w, 0, 1);
                let _ = p.as_i64s(); // type confusion must panic -> abort
            }
        }),
    );
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("expected I64s"));
}

#[test]
fn recv_from_out_of_range_rank_aborts() {
    let world = World::new(Cluster::mini(1, 2), fast_watchdog());
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 0 {
                ctx.send(&w, 99, 1, Payload::Token); // no rank 99
            }
        }),
    );
    assert!(world.join_all().is_err());
}

#[test]
fn rms_rejects_overcommit_and_conflicts() {
    let mut rms = Rms::new(Cluster::mini(2, 4));
    assert!(rms.plan_allocation(3, AllocPolicy::WholeNodes).is_err());
    let a = rms.plan_allocation(2, AllocPolicy::WholeNodes).unwrap();
    rms.claim(&a).unwrap();
    assert!(rms.claim(&a).is_err(), "double claim must conflict");
}

#[test]
fn scenario_rejects_capacity_overflow() {
    let s = Scenario {
        cluster: Cluster::mini(4, 4),
        initial_nodes: 1,
        target_nodes: 9, // only 4 nodes exist
        ..Default::default()
    };
    assert!(run_reconfiguration(&s).is_err());
}

#[test]
fn hypercube_on_heterogeneous_cluster_fails_loudly() {
    // The paper: "the Hypercube strategy is not included [on NASP] because
    // it is unable to correctly spawn the processes". Our implementation
    // turns that into a loud validation failure.
    let s = Scenario {
        prepare_parallel: false,
        ..Scenario::nasp(1, 4).with(Method::Merge, SpawnStrategy::ParallelHypercube)
    };
    let err = run_reconfiguration(&s).unwrap_err();
    assert!(
        format!("{err:#}").contains("homogeneous"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn zombie_terminate_order_drains_parked_rank() {
    use paraspawn::simmpi::ZombieOrder;
    let world = World::new(Cluster::mini(1, 2), fast_watchdog());
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 1 {
                let order = ctx.park_zombie();
                assert!(matches!(order, ZombieOrder::Terminate { .. }));
            } else {
                ctx.charge(0.5);
                ctx.world()
                    .clone()
                    .signal_zombie(ctx.pid() + 1, ZombieOrder::Terminate { at: ctx.clock() });
            }
        }),
    );
    world.join_all().unwrap();
}

#[test]
fn abort_is_idempotent_and_first_reason_wins() {
    let world = World::new(Cluster::mini(1, 1), fast_watchdog());
    world.abort("first");
    world.abort("second");
    world.launch(&[(0, 1)], Arc::new(|ctx: Ctx, w: Comm| {
        // Any blocking op must observe the abort.
        let _ = ctx.recv(&w, 0, 1);
    }));
    let err = world.join_all().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("first"), "got: {msg}");
}
