//! Failure injection: the simulator must fail *loudly and promptly* on
//! protocol errors (panics, deadlocks, mismatched collectives), never
//! hang, and RMS/plan validation must reject inconsistent inputs.

use paraspawn::config::{CostModel, SimConfig};
use paraspawn::coordinator::{run_reconfiguration, Scenario};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::{AllocPolicy, Rms};
use paraspawn::simmpi::{Comm, Ctx, Payload, World};
use paraspawn::topology::Cluster;
use std::sync::Arc;
use std::time::Instant;

/// A fast deadlock detector whose budget scales with the world size
/// (10 ms per rank on top of the base), so large-cluster protocol tests
/// measure stalls rather than CI machine speed.
fn fast_watchdog(total_ranks: usize) -> SimConfig {
    SimConfig {
        cost: CostModel::mn5().deterministic(),
        ..Default::default()
    }
    .with_scaled_watchdog(1.5, total_ranks)
}

#[test]
fn mid_protocol_panic_unblocks_collective_peers() {
    let world = World::new(Cluster::mini(1, 4), fast_watchdog(4));
    world.launch(
        &[(0, 4)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 3 {
                panic!("injected failure before barrier");
            }
            ctx.barrier(&w); // would deadlock without abort propagation
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("injected failure"));
    assert!(t0.elapsed().as_secs_f64() < 5.0, "abort must release peers promptly");
}

#[test]
fn connect_to_unpublished_service_hits_watchdog() {
    let world = World::new(Cluster::mini(1, 1), fast_watchdog(1));
    world.launch(
        &[(0, 1)],
        Arc::new(|ctx: Ctx, _w: Comm| {
            let _ = ctx.lookup_name("service-that-never-exists");
        }),
    );
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("watchdog"));
}

#[test]
fn mismatched_collective_participation_aborts() {
    // Rank 0 calls barrier twice, rank 1 once, on a 2-rank comm: the
    // second instance can never complete -> watchdog.
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            ctx.barrier(&w);
            if w.rank() == 0 {
                ctx.barrier(&w);
            }
        }),
    );
    assert!(world.join_all().is_err());
}

#[test]
fn wrong_payload_type_panics_cleanly() {
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 0 {
                ctx.send(&w, 1, 1, Payload::Str("not ints".into()));
            } else {
                let (p, _, _) = ctx.recv(&w, 0, 1);
                let _ = p.as_i64s(); // type confusion must panic -> abort
            }
        }),
    );
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("expected I64s"));
}

#[test]
fn recv_from_out_of_range_rank_aborts() {
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 0 {
                ctx.send(&w, 99, 1, Payload::Token); // no rank 99
            }
        }),
    );
    assert!(world.join_all().is_err());
}

#[test]
fn rms_rejects_overcommit_and_conflicts() {
    let mut rms = Rms::new(Cluster::mini(2, 4));
    assert!(rms.plan_allocation(3, AllocPolicy::WholeNodes).is_err());
    let a = rms.plan_allocation(2, AllocPolicy::WholeNodes).unwrap();
    rms.claim(&a).unwrap();
    assert!(rms.claim(&a).is_err(), "double claim must conflict");
}

#[test]
fn scenario_rejects_capacity_overflow() {
    let s = Scenario {
        cluster: Cluster::mini(4, 4),
        initial_nodes: 1,
        target_nodes: 9, // only 4 nodes exist
        ..Default::default()
    };
    assert!(run_reconfiguration(&s).is_err());
}

#[test]
fn hypercube_on_heterogeneous_cluster_fails_loudly() {
    // The paper: "the Hypercube strategy is not included [on NASP] because
    // it is unable to correctly spawn the processes". Our implementation
    // turns that into a loud validation failure.
    let s = Scenario {
        prepare_parallel: false,
        ..Scenario::nasp(1, 4).with(Method::Merge, SpawnStrategy::ParallelHypercube)
    };
    let err = run_reconfiguration(&s).unwrap_err();
    assert!(
        format!("{err:#}").contains("homogeneous"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn zombie_terminate_order_drains_parked_rank() {
    use paraspawn::simmpi::ZombieOrder;
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 1 {
                let order = ctx.park_zombie();
                assert!(matches!(order, ZombieOrder::Terminate { .. }));
            } else {
                ctx.charge(0.5);
                ctx.world()
                    .clone()
                    .signal_zombie(ctx.pid() + 1, ZombieOrder::Terminate { at: ctx.clock() });
            }
        }),
    );
    world.join_all().unwrap();
}

#[test]
fn abort_is_idempotent_and_first_reason_wins() {
    let world = World::new(Cluster::mini(1, 1), fast_watchdog(1));
    world.abort("first");
    world.abort("second");
    world.launch(&[(0, 1)], Arc::new(|ctx: Ctx, w: Comm| {
        // Any blocking op must observe the abort.
        let _ = ctx.recv(&w, 0, 1);
    }));
    let err = world.join_all().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("first"), "got: {msg}");
}

// ---------------------------------------------------------------------------
// Asynchronous-expansion failure injection: faults between
// expand_async_initiate and expand_async_complete must abort the whole
// simulation promptly (no hangs past the watchdog window).
// ---------------------------------------------------------------------------

fn async_spec(
    plan: paraspawn::mam::Plan,
    t_start: f64,
) -> paraspawn::mam::ReconfigSpec {
    paraspawn::mam::ReconfigSpec {
        plan: Arc::new(plan),
        t_start,
        data_bytes: 0,
        cont: Arc::new(|_ctx: Ctx, _job: paraspawn::mam::JobCtx| {}),
        zombie_pids: Vec::new(),
    }
}

fn async_expansion_plan() -> paraspawn::mam::Plan {
    // 1 -> 2 nodes, Merge + Hypercube (the async-eligible shape).
    paraspawn::mam::Plan::new(
        0,
        Method::Merge,
        SpawnStrategy::ParallelHypercube,
        vec![0, 1],
        vec![2, 2],
        vec![2, 0],
    )
}

#[test]
fn panic_between_async_initiate_and_complete_aborts_peers() {
    use paraspawn::mam::{driver, JobCtx};
    let world = World::new(Cluster::mini(2, 2), fast_watchdog(4));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, wc: Comm| {
            let job = JobCtx { app: wc.clone(), mcw: wc, epoch: 0, zombie_pids: Vec::new() };
            let spec = async_spec(async_expansion_plan(), ctx.clock());
            let pending = driver::expand_async_initiate(&ctx, &job, &spec);
            if job.app.rank() == 1 {
                panic!("injected failure during async overlap");
            }
            // Rank 0 proceeds to completion; the merge can never finish
            // because rank 1 died, so abort propagation must unwind it.
            let _ = driver::expand_async_complete(&ctx, &job, pending);
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(
        format!("{err}").contains("injected failure"),
        "unexpected: {err}"
    );
    assert!(t0.elapsed().as_secs_f64() < 10.0, "abort must release async peers promptly");
}

#[test]
fn abandoned_async_completion_hits_watchdog_not_hang() {
    use paraspawn::mam::{driver, JobCtx};
    let world = World::new(Cluster::mini(2, 2), fast_watchdog(4));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, wc: Comm| {
            let job = JobCtx { app: wc.clone(), mcw: wc, epoch: 0, zombie_pids: Vec::new() };
            let spec = async_spec(async_expansion_plan(), ctx.clock());
            // Initiate and then never complete: the spawned groups stay
            // blocked in their final merge. The watchdog must fire.
            let pending = driver::expand_async_initiate(&ctx, &job, &spec);
            drop(pending);
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("watchdog"), "unexpected: {err}");
    // Scaled budget: 1.5 s base + 10 ms x 4 ranks, plus wakeup slack.
    assert!(t0.elapsed().as_secs_f64() < 20.0, "watchdog must bound the hang");
}
