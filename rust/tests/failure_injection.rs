//! Failure injection: the simulator must fail *loudly and promptly* on
//! protocol errors (panics, deadlocks, mismatched collectives), never
//! hang, and RMS/plan validation must reject inconsistent inputs.

use paraspawn::config::{CostModel, SimConfig};
use paraspawn::coordinator::sweep::ClusterKind;
use paraspawn::coordinator::wsweep::{
    analytic_pricers, auto_pricers, default_costs, kind_cost_model, scalar_pricers,
    stateful_pricers,
};
use paraspawn::coordinator::{run_reconfiguration, Scenario};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::gen::{expand_manifest, parse_manifest};
use paraspawn::rms::sched::{
    schedule_trace, schedule_with_pricer, Outage, SchedPolicy, SchedResult, StatefulPricer,
    Trace,
};
use paraspawn::rms::workload::{JobSpec, ReconfigCostModel, WorkloadError};
use paraspawn::rms::{AllocPolicy, Rms};
use paraspawn::simmpi::{Comm, Ctx, Payload, World};
use paraspawn::topology::Cluster;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// A fast deadlock detector whose budget scales with the world size
/// (10 ms per rank on top of the base), so large-cluster protocol tests
/// measure stalls rather than CI machine speed.
fn fast_watchdog(total_ranks: usize) -> SimConfig {
    SimConfig {
        cost: CostModel::mn5().deterministic(),
        ..Default::default()
    }
    .with_scaled_watchdog(1.5, total_ranks)
}

#[test]
fn mid_protocol_panic_unblocks_collective_peers() {
    let world = World::new(Cluster::mini(1, 4), fast_watchdog(4));
    world.launch(
        &[(0, 4)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 3 {
                panic!("injected failure before barrier");
            }
            ctx.barrier(&w); // would deadlock without abort propagation
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("injected failure"));
    assert!(t0.elapsed().as_secs_f64() < 5.0, "abort must release peers promptly");
}

#[test]
fn connect_to_unpublished_service_hits_watchdog() {
    let world = World::new(Cluster::mini(1, 1), fast_watchdog(1));
    world.launch(
        &[(0, 1)],
        Arc::new(|ctx: Ctx, _w: Comm| {
            let _ = ctx.lookup_name("service-that-never-exists");
        }),
    );
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("watchdog"));
}

#[test]
fn mismatched_collective_participation_aborts() {
    // Rank 0 calls barrier twice, rank 1 once, on a 2-rank comm: the
    // second instance can never complete -> watchdog.
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            ctx.barrier(&w);
            if w.rank() == 0 {
                ctx.barrier(&w);
            }
        }),
    );
    assert!(world.join_all().is_err());
}

#[test]
fn wrong_payload_type_panics_cleanly() {
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 0 {
                ctx.send(&w, 1, 1, Payload::Str("not ints".into()));
            } else {
                let (p, _, _) = ctx.recv(&w, 0, 1);
                let _ = p.as_i64s(); // type confusion must panic -> abort
            }
        }),
    );
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("expected I64s"));
}

#[test]
fn recv_from_out_of_range_rank_aborts() {
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 0 {
                ctx.send(&w, 99, 1, Payload::Token); // no rank 99
            }
        }),
    );
    assert!(world.join_all().is_err());
}

#[test]
fn rms_rejects_overcommit_and_conflicts() {
    let mut rms = Rms::new(Cluster::mini(2, 4));
    assert!(rms.plan_allocation(3, AllocPolicy::WholeNodes).is_err());
    let a = rms.plan_allocation(2, AllocPolicy::WholeNodes).unwrap();
    rms.claim(&a).unwrap();
    assert!(rms.claim(&a).is_err(), "double claim must conflict");
}

#[test]
fn scenario_rejects_capacity_overflow() {
    let s = Scenario {
        cluster: Cluster::mini(4, 4),
        initial_nodes: 1,
        target_nodes: 9, // only 4 nodes exist
        ..Default::default()
    };
    assert!(run_reconfiguration(&s).is_err());
}

#[test]
fn hypercube_on_heterogeneous_cluster_fails_loudly() {
    // The paper: "the Hypercube strategy is not included [on NASP] because
    // it is unable to correctly spawn the processes". Our implementation
    // turns that into a loud validation failure.
    let s = Scenario {
        prepare_parallel: false,
        ..Scenario::nasp(1, 4).with(Method::Merge, SpawnStrategy::ParallelHypercube)
    };
    let err = run_reconfiguration(&s).unwrap_err();
    assert!(
        format!("{err:#}").contains("homogeneous"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn zombie_terminate_order_drains_parked_rank() {
    use paraspawn::simmpi::ZombieOrder;
    let world = World::new(Cluster::mini(1, 2), fast_watchdog(2));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, w: Comm| {
            if w.rank() == 1 {
                let order = ctx.park_zombie();
                assert!(matches!(order, ZombieOrder::Terminate { .. }));
            } else {
                ctx.charge(0.5);
                ctx.world()
                    .clone()
                    .signal_zombie(ctx.pid() + 1, ZombieOrder::Terminate { at: ctx.clock() });
            }
        }),
    );
    world.join_all().unwrap();
}

#[test]
fn abort_is_idempotent_and_first_reason_wins() {
    let world = World::new(Cluster::mini(1, 1), fast_watchdog(1));
    world.abort("first");
    world.abort("second");
    world.launch(&[(0, 1)], Arc::new(|ctx: Ctx, w: Comm| {
        // Any blocking op must observe the abort.
        let _ = ctx.recv(&w, 0, 1);
    }));
    let err = world.join_all().unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("first"), "got: {msg}");
}

// ---------------------------------------------------------------------------
// Asynchronous-expansion failure injection: faults between
// expand_async_initiate and expand_async_complete must abort the whole
// simulation promptly (no hangs past the watchdog window).
// ---------------------------------------------------------------------------

fn async_spec(
    plan: paraspawn::mam::Plan,
    t_start: f64,
) -> paraspawn::mam::ReconfigSpec {
    paraspawn::mam::ReconfigSpec {
        plan: Arc::new(plan),
        t_start,
        data_bytes: 0,
        cont: Arc::new(|_ctx: Ctx, _job: paraspawn::mam::JobCtx| {}),
        zombie_pids: Vec::new(),
    }
}

fn async_expansion_plan() -> paraspawn::mam::Plan {
    // 1 -> 2 nodes, Merge + Hypercube (the async-eligible shape).
    paraspawn::mam::Plan::new(
        0,
        Method::Merge,
        SpawnStrategy::ParallelHypercube,
        vec![0, 1],
        vec![2, 2],
        vec![2, 0],
    )
}

#[test]
fn panic_between_async_initiate_and_complete_aborts_peers() {
    use paraspawn::mam::{driver, JobCtx};
    let world = World::new(Cluster::mini(2, 2), fast_watchdog(4));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, wc: Comm| {
            let job = JobCtx { app: wc.clone(), mcw: wc, epoch: 0, zombie_pids: Vec::new() };
            let spec = async_spec(async_expansion_plan(), ctx.clock());
            let pending = driver::expand_async_initiate(&ctx, &job, &spec);
            if job.app.rank() == 1 {
                panic!("injected failure during async overlap");
            }
            // Rank 0 proceeds to completion; the merge can never finish
            // because rank 1 died, so abort propagation must unwind it.
            let _ = driver::expand_async_complete(&ctx, &job, pending);
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(
        format!("{err}").contains("injected failure"),
        "unexpected: {err}"
    );
    assert!(t0.elapsed().as_secs_f64() < 10.0, "abort must release async peers promptly");
}

#[test]
fn abandoned_async_completion_hits_watchdog_not_hang() {
    use paraspawn::mam::{driver, JobCtx};
    let world = World::new(Cluster::mini(2, 2), fast_watchdog(4));
    world.launch(
        &[(0, 2)],
        Arc::new(|ctx: Ctx, wc: Comm| {
            let job = JobCtx { app: wc.clone(), mcw: wc, epoch: 0, zombie_pids: Vec::new() };
            let spec = async_spec(async_expansion_plan(), ctx.clock());
            // Initiate and then never complete: the spawned groups stay
            // blocked in their final merge. The watchdog must fire.
            let pending = driver::expand_async_initiate(&ctx, &job, &spec);
            drop(pending);
        }),
    );
    let t0 = Instant::now();
    let err = world.join_all().unwrap_err();
    assert!(format!("{err}").contains("watchdog"), "unexpected: {err}");
    // Scaled budget: 1.5 s base + 10 ms x 4 ranks, plus wakeup slack.
    assert!(t0.elapsed().as_secs_f64() < 20.0, "watchdog must bound the hang");
}

// ---------------------------------------------------------------------------
// Trace-level failure injection: mid-trace node outages
// (rms::gen manifests -> rms::sched::schedule_trace) must be absorbed
// by forced shrink/requeue, conserve node-seconds under every pricing
// arm, and cost the outage-free path nothing.
// ---------------------------------------------------------------------------

fn conservation(label: &str, r: &SchedResult) {
    let lhs = r.work_node_seconds
        + r.reconfig_node_seconds
        + r.idle_node_seconds
        + r.outage_node_seconds;
    let rel = (lhs - r.total_node_seconds).abs() / r.total_node_seconds.max(1.0);
    assert!(
        rel < 1e-6,
        "{label}: work + reconfig + idle + outage = {lhs} but total = {} (rel {rel:e})",
        r.total_node_seconds
    );
}

/// A mid-trace outage on a cluster saturated by one malleable job is
/// absorbed by a forced (priced) shrink; the downed node-time lands in
/// the outage ledger and the run still conserves node-seconds.
#[test]
fn outage_forces_priced_shrink_on_a_malleable_runner() {
    let cluster = Cluster::mini(8, 4);
    let jobs = vec![JobSpec {
        arrival: 0.0,
        work: 8000.0,
        min_nodes: 2,
        max_nodes: 8,
        malleable: true,
    }];
    let outage = Outage { start: 10.0, nodes: 4, duration: 50.0 };
    let run = |outages: Vec<Outage>| {
        let mut pricer = ReconfigCostModel::ts(1.0);
        let trace = Trace { jobs: jobs.clone(), checkpoint_s: Vec::new(), outages };
        schedule_trace(
            &cluster,
            AllocPolicy::WholeNodes,
            SchedPolicy::Malleable,
            &mut pricer,
            &trace,
        )
        .unwrap()
    };
    let plain = run(Vec::new());
    let hit = run(vec![outage]);

    assert!(hit.shrinks > plain.shrinks, "the outage must force a shrink: {hit:?}");
    assert!(hit.expands >= plain.expands, "the runner re-expands after the outage ends");
    // 4 nodes down for 50 s, no requeue -> exactly 200 downed
    // node-seconds and no lost work.
    assert!(
        (hit.outage_node_seconds - 200.0).abs() < 1e-9,
        "outage ledger {} != 200",
        hit.outage_node_seconds
    );
    assert!(hit.makespan > plain.makespan, "losing capacity cannot speed the run up");
    conservation("forced shrink", &hit);
    conservation("outage-free", &plain);
    assert_eq!(plain.outage_node_seconds, 0.0);
}

/// With only a rigid full-width runner, the outage cannot shrink
/// anyone: the victim is requeued (losing its progress), the job
/// restarts after the outage ends, and both the downed node-time and
/// the lost work land in the outage ledger.
#[test]
fn outage_requeues_a_rigid_runner_and_accounts_the_lost_work() {
    let cluster = Cluster::mini(8, 4);
    let jobs = vec![JobSpec {
        arrival: 0.0,
        work: 800.0,
        min_nodes: 8,
        max_nodes: 8,
        malleable: false,
    }];
    let trace = Trace {
        jobs,
        checkpoint_s: Vec::new(),
        outages: vec![Outage { start: 10.0, nodes: 1, duration: 50.0 }],
    };
    let mut pricer = ReconfigCostModel::ts(1.0);
    let r = schedule_trace(
        &cluster,
        AllocPolicy::WholeNodes,
        SchedPolicy::Fcfs,
        &mut pricer,
        &trace,
    )
    .unwrap();

    // Runs 0..10 on 8 nodes (80 node-seconds lost), waits out the
    // outage (1 node down for 50 s), restarts at t = 60 and runs its
    // full 100 s: finish 160, outage ledger 80 + 50 = 130.
    assert_eq!(r.shrinks, 0, "a rigid job cannot be shrunk: {r:?}");
    assert!((r.jobs[0].finish - 160.0).abs() < 1e-9, "finish {} != 160", r.jobs[0].finish);
    assert!((r.jobs[0].wait - 60.0).abs() < 1e-9, "final admission wait {}", r.jobs[0].wait);
    assert!(
        (r.outage_node_seconds - 130.0).abs() < 1e-9,
        "outage ledger {} != 130",
        r.outage_node_seconds
    );
    conservation("requeue", &r);
}

fn smoke_manifest_text() -> String {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("examples/manifests/ci_smoke.conf");
    std::fs::read_to_string(&path).expect("bundled smoke manifest readable")
}

/// Node-seconds conserve under all seven pricing arms on a generated
/// outage-bearing, checkpoint-bearing trace, for every scheduling
/// policy: work + reconfig + idle + outage always equals the
/// `total_nodes x makespan` budget.
#[test]
fn node_seconds_conserve_under_all_seven_pricing_arms() {
    let manifest = parse_manifest(&smoke_manifest_text()).unwrap();
    let traces = expand_manifest(&manifest, 42);
    let (name, diurnal) = &traces[0];
    assert_eq!(name, "diurnal");
    assert!(!diurnal.outages.is_empty() && !diurnal.checkpoint_s.is_empty());

    let cluster = Cluster::mini(8, 4);
    let cost = kind_cost_model(ClusterKind::Mini);
    let mut arms = scalar_pricers(&default_costs());
    arms.extend(analytic_pricers(&cost, None, 0));
    arms.extend(stateful_pricers(&cost, None, 0));
    arms.extend(auto_pricers(&cost, 0));
    assert_eq!(arms.len(), 7);

    for spec in &arms {
        for &policy in SchedPolicy::ALL.iter() {
            let mut pricer = spec.build(&cluster);
            let r = schedule_trace(
                &cluster,
                AllocPolicy::WholeNodes,
                policy,
                pricer.as_mut(),
                diurnal,
            )
            .unwrap();
            assert!(r.outage_node_seconds > 0.0, "{}: the outage must cost", spec.label);
            conservation(&format!("{}/{}", spec.label, policy.name()), &r);
        }
    }
}

/// A zero-overlay trace schedules bit-identically to the plain
/// outage-free entry point, under both a scalar and a stateful pricer,
/// for every policy — the overlay machinery costs the legacy path
/// nothing, not even an event count.
#[test]
fn zero_outage_trace_is_bit_identical_to_the_outage_free_path() {
    let manifest = parse_manifest(&smoke_manifest_text()).unwrap();
    let traces = expand_manifest(&manifest, 42);
    let (name, flat) = &traces[1];
    assert_eq!(name, "flat");
    assert!(flat.checkpoint_s.is_empty() && flat.outages.is_empty());
    assert!(flat.jobs.len() >= 50, "flat control must stay non-trivial");

    let cluster = Cluster::mini(8, 4);
    let cost = CostModel::mn5();
    for &policy in SchedPolicy::ALL.iter() {
        let mut a = ReconfigCostModel::ts(1.0);
        let mut b = ReconfigCostModel::ts(1.0);
        let via_trace =
            schedule_trace(&cluster, AllocPolicy::WholeNodes, policy, &mut a, flat).unwrap();
        let via_jobs =
            schedule_with_pricer(&cluster, AllocPolicy::WholeNodes, policy, &mut b, &flat.jobs)
                .unwrap();
        assert_eq!(via_trace, via_jobs, "{}: scalar paths diverged", policy.name());

        let mut a = StatefulPricer::ts(cluster.clone(), cost.clone());
        let mut b = StatefulPricer::ts(cluster.clone(), cost.clone());
        let via_trace =
            schedule_trace(&cluster, AllocPolicy::WholeNodes, policy, &mut a, flat).unwrap();
        let via_jobs =
            schedule_with_pricer(&cluster, AllocPolicy::WholeNodes, policy, &mut b, &flat.jobs)
                .unwrap();
        assert_eq!(via_trace, via_jobs, "{}: stateful paths diverged", policy.name());
    }
}

/// Malformed overlays are rejected loudly before any scheduling runs.
#[test]
fn malformed_trace_overlays_are_rejected() {
    let cluster = Cluster::mini(2, 4);
    let jobs = vec![JobSpec {
        arrival: 0.0,
        work: 10.0,
        min_nodes: 1,
        max_nodes: 1,
        malleable: false,
    }];
    let run = |trace: &Trace| {
        let mut pricer = ReconfigCostModel::ts(1.0);
        schedule_trace(&cluster, AllocPolicy::WholeNodes, SchedPolicy::Fcfs, &mut pricer, trace)
    };
    let cases = [
        Trace { jobs: jobs.clone(), checkpoint_s: vec![1.0, 2.0], outages: Vec::new() },
        Trace { jobs: jobs.clone(), checkpoint_s: vec![-1.0], outages: Vec::new() },
        Trace {
            jobs: jobs.clone(),
            checkpoint_s: Vec::new(),
            outages: vec![Outage { start: 0.0, nodes: 0, duration: 1.0 }],
        },
        Trace {
            jobs: jobs.clone(),
            checkpoint_s: Vec::new(),
            outages: vec![Outage { start: f64::NAN, nodes: 1, duration: 1.0 }],
        },
        Trace {
            jobs,
            checkpoint_s: Vec::new(),
            outages: vec![Outage { start: 0.0, nodes: 1, duration: 0.0 }],
        },
    ];
    for (i, trace) in cases.iter().enumerate() {
        let err = run(trace).unwrap_err();
        assert!(
            matches!(err, WorkloadError::Overlay { .. }),
            "case {i}: expected an overlay error, got {err}"
        );
    }
}
