#!/usr/bin/env python3
"""Replay-throughput regression gate for CI.

Compares a fresh ``BENCH_replay.json`` (written by
``cargo bench --bench bench_replay``) against the committed
``BENCH_replay.baseline.json`` and fails if any arm's jobs/sec falls
more than the allowed slack below its baseline.

The baseline is a deliberately conservative floor, not a fresh
measurement: CI runners are noisy and heterogeneous, so the committed
numbers sit far below what any release build achieves, and the 20%
slack on top absorbs scheduler jitter. Ratchet the floor upward by
editing the baseline file when the measured rates have stably moved.

Usage: bench_gate.py MEASURED_JSON BASELINE_JSON

Exit code 0 when every gated arm passes, 1 otherwise. Stdlib only.
"""

import json
import sys


def main(argv):
    if len(argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        measured = json.load(f)
    with open(argv[2]) as f:
        baseline = json.load(f)

    if measured.get("schema") != "paraspawn-bench-replay-v1":
        print(f"unexpected schema in {argv[1]}: {measured.get('schema')!r}", file=sys.stderr)
        return 1

    slack = float(baseline.get("slack", 0.8))
    floors = baseline.get("min_jobs_per_sec", {})
    rates = {arm["name"]: arm["jobs_per_sec"] for arm in measured.get("arms", [])}

    failed = False
    for name, floor in sorted(floors.items()):
        got = rates.get(name)
        if got is None:
            print(f"FAIL {name}: arm missing from {argv[1]}")
            failed = True
            continue
        limit = slack * float(floor)
        verdict = "ok" if got >= limit else "FAIL"
        print(f"{verdict} {name}: {got:.1f} jobs/s (floor {floor:.1f} x {slack:.2f} = {limit:.1f})")
        if got < limit:
            failed = True

    ref = measured.get("reference", {})
    speedup = measured.get("speedup_vs_reference")
    if speedup is not None:
        print(
            f"info speedup_vs_reference: {speedup:.2f}x "
            f"(reference {ref.get('jobs_per_sec', 0):.1f} jobs/s on {ref.get('jobs', 0)} jobs)"
        )
    min_speedup = baseline.get("min_speedup_vs_reference")
    if min_speedup is not None and speedup is not None and speedup < float(min_speedup):
        print(f"FAIL speedup_vs_reference: {speedup:.2f}x < {float(min_speedup):.2f}x")
        failed = True

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
