//! Quickstart: one parallel expansion + one TS shrink on a small cluster.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use paraspawn::coordinator::figures::describe_report;
use paraspawn::coordinator::{run_reconfiguration, Scenario};
use paraspawn::mam::{Method, SpawnStrategy};

fn main() -> anyhow::Result<()> {
    // Expand a job from 1 to 4 MN5 nodes (112 -> 448 ranks) with the
    // paper's parallel Hypercube strategy (section 4.1).
    let expand = Scenario::mn5(1, 4).with(Method::Merge, SpawnStrategy::ParallelHypercube);
    let report = run_reconfiguration(&expand)?;
    println!("--- expansion, Merge + Hypercube ---");
    println!("{}\n", describe_report(&report));

    // The same expansion with the classic single-spawn Merge: slightly
    // faster, but its multi-node child MCW forbids TS shrinking later.
    let plain = Scenario::mn5(1, 4).with(Method::Merge, SpawnStrategy::Plain);
    let report_plain = run_reconfiguration(&plain)?;
    println!("--- expansion, plain Merge (reference) ---");
    println!("{}\n", describe_report(&report_plain));

    // Shrink 4 -> 1 nodes. Thanks to the parallel expansion beforehand
    // (prepare step), every expansion MCW sits on one node, so the Merge
    // shrink is a TS: no spawning, whole nodes returned to the RMS.
    let shrink = Scenario {
        prepare_parallel: true,
        ..Scenario::mn5(4, 1).with(Method::Merge, SpawnStrategy::Plain)
    };
    let report_ts = run_reconfiguration(&shrink)?;
    println!("--- shrink, Merge = Termination Shrinkage ---");
    println!("{}\n", describe_report(&report_ts));

    println!(
        "TS shrink vs parallel expansion: {:.0}x faster",
        report.total_time / report_ts.total_time
    );
    Ok(())
}
