//! The analytic engine at paper scale: the full 4a/4b/6a/6b preset
//! matrices (MN5 at 112 cores/node + heterogeneous NASP, every node
//! pair, five repetitions per cell) evaluated single-threaded in well
//! under a second — the same grid takes minutes through the
//! thread-per-rank simulator.
//!
//! Run with `cargo run --release --example analytic_sweep`.

use paraspawn::coordinator::sweep::{preset_group, run_tasks_engine, Engine, SweepTask};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let matrices = preset_group("paper").expect("paper preset group exists");
    let tasks: Vec<SweepTask> = matrices
        .iter()
        .flat_map(|m| m.clone().reps(5).tasks())
        .collect();
    let n_tasks = tasks.len();

    let t0 = Instant::now();
    let results = run_tasks_engine(tasks, 1, Engine::Analytic)?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "analytic sweep: {} scenarios ({} cells) across 4a/4b/6a/6b in {:.3}s single-threaded",
        n_tasks,
        results.samples.len(),
        wall
    );
    // A taste of the output: the largest MN5 expansion cells.
    for (cell, xs) in results.samples.iter().filter(|(c, _)| {
        c.cluster == "mn5" && c.initial_nodes == 1 && c.target_nodes == 32
    }) {
        println!(
            "  mn5 1->32 nodes [{}]: {:.3} s resize time",
            cell.config, xs[0]
        );
    }

    // The acceptance bar this example demonstrates: full paper grids at
    // 112 cores/node in under one second, single-threaded. Shared CI
    // runners can override the budget (machine speed is not a defect).
    let budget: f64 = std::env::var("PARASPAWN_TIME_BUDGET_S")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    assert!(
        wall < budget,
        "analytic paper sweep took {wall:.3}s (budget {budget:.1}s single-threaded)"
    );
    println!("OK: under the {budget:.1}-second budget");
    Ok(())
}
