//! The sweep engine on a user-defined scenario grid: build a
//! [`ScenarioMatrix`], run it on a thread pool, and read the unified
//! sink (medians + order-statistic CIs + per-phase breakdown).
//!
//! The same engine powers the paper-figure harness (`paraspawn figures`)
//! and the `paraspawn sweep` subcommand; because every repetition is
//! bit-reproducible for its derived seed, the results below are
//! identical for any `--threads` value.
//!
//! ```bash
//! cargo run --release --example sweep_matrix
//! ```

use paraspawn::coordinator::sweep::{
    default_threads, mn5_shrink_configs, run_matrix, ClusterKind, MethodConfig, ScenarioMatrix,
};
use paraspawn::mam::{Method, SpawnStrategy};

fn main() -> anyhow::Result<()> {
    // A custom grid: three expansion families on the mini test cluster
    // (8 x 4-core nodes), every expansion pair over {1, 2, 4, 8} nodes.
    use SpawnStrategy::*;
    let configs = vec![
        MethodConfig { label: "M", method: Method::Merge, strategy: Plain },
        MethodConfig { label: "M+HC", method: Method::Merge, strategy: ParallelHypercube },
        MethodConfig { label: "M+ID", method: Method::Merge, strategy: ParallelDiffusive },
    ];
    let matrix = ScenarioMatrix::new()
        .clusters(vec![ClusterKind::Mini])
        .configs(configs)
        .expansions(&[1, 2, 4, 8])
        .reps(5)
        .seed(0xF16);

    let threads = default_threads();
    println!("running {} tasks on {} threads...\n", matrix.len(), threads);
    let t0 = std::time::Instant::now();
    let results = run_matrix(&matrix, threads)?;
    println!("== expansion summary (medians + 95% CI) ==");
    print!("{}", results.summary_table().to_ascii());
    println!("\n== mean per-phase breakdown ==");
    print!("{}", results.phase_table().to_ascii());

    // The shrink side of the same grid, declared just as tersely.
    let shrinks = ScenarioMatrix::new()
        .clusters(vec![ClusterKind::Mini])
        .configs(mn5_shrink_configs())
        .shrinks(&[1, 2, 4, 8])
        .reps(5)
        .seed(0xF16);
    let shrink_results = run_matrix(&shrinks, threads)?;
    println!("\n== shrink summary ==");
    print!("{}", shrink_results.summary_table().to_ascii());

    println!(
        "\n{} samples total in {:.2}s wall-clock",
        results.total_samples() + shrink_results.total_samples(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
