//! RMS workload simulation: the system-level payoff of malleability
//! (§1: DRM "can reduce workload makespan, substantially decreasing job
//! waiting times"). Compares a rigid schedule against DRM with TS-cost
//! shrinks (this paper) and with SS-cost shrinks (respawn-based), using
//! reconfiguration costs measured by the figure harness.
//!
//! ```bash
//! cargo run --release --example rms_workload
//! ```

use paraspawn::coordinator::{run_reconfiguration, Scenario};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::workload::{simulate, synthetic_workload, ReconfigCostModel};
use paraspawn::util::csvout::Table;

fn main() -> anyhow::Result<()> {
    // Measure real (virtual-time) reconfiguration costs on the simulator
    // rather than hardcoding them.
    let expand = run_reconfiguration(
        &Scenario::mn5(1, 2).with(Method::Merge, SpawnStrategy::ParallelHypercube),
    )?
    .total_time;
    let ts_shrink = run_reconfiguration(&Scenario {
        prepare_parallel: true,
        ..Scenario::mn5(2, 1).with(Method::Merge, SpawnStrategy::Plain)
    })?
    .total_time;
    let ss_shrink = run_reconfiguration(
        &Scenario::mn5(2, 1).with(Method::Baseline, SpawnStrategy::ParallelHypercube),
    )?
    .total_time;
    println!(
        "measured costs: expand {:.3}s, TS shrink {:.6}s, SS shrink {:.3}s\n",
        expand, ts_shrink, ss_shrink
    );

    let nodes = 32;
    let jobs = synthetic_workload(60, nodes, 0.6, 2024);
    let rigid = simulate(nodes, &jobs, false, ReconfigCostModel::ts(expand))?;
    let drm_ts = simulate(
        nodes,
        &jobs,
        true,
        ReconfigCostModel { expand_cost: expand, shrink_cost: ts_shrink },
    )?;
    let drm_ss = simulate(
        nodes,
        &jobs,
        true,
        ReconfigCostModel { expand_cost: expand, shrink_cost: ss_shrink },
    )?;

    let mut t = Table::new(vec!["policy", "makespan_s", "mean_wait_s", "turnaround_s", "reconfigs"]);
    for (name, r) in [("rigid", &rigid), ("DRM + TS (this paper)", &drm_ts), ("DRM + SS", &drm_ss)] {
        t.push_row(vec![
            name.to_string(),
            format!("{:.1}", r.makespan),
            format!("{:.1}", r.mean_wait),
            format!("{:.1}", r.mean_turnaround),
            r.reconfigurations.to_string(),
        ]);
    }
    print!("{}", t.to_ascii());

    println!(
        "\nDRM+TS improves makespan by {:.1}% over rigid ({:.1}% for DRM+SS)",
        100.0 * (1.0 - drm_ts.makespan / rigid.makespan),
        100.0 * (1.0 - drm_ss.makespan / rigid.makespan),
    );
    assert!(drm_ts.makespan <= rigid.makespan);
    Ok(())
}
