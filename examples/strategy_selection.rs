//! MaM-style automatic configuration selection through the L2 cost-model
//! kernel: the coordinator builds one feature row per candidate
//! (method x strategy), scores all of them in a single PJRT call and
//! picks the cheapest given the job's expected future shrinks — the
//! tradeoff at the heart of the paper (parallel spawning costs a little
//! at expansion, enables very cheap TS shrinks later).
//!
//! ```bash
//! make artifacts && cargo run --release --example strategy_selection
//! ```

use paraspawn::config::CostModel;
use paraspawn::coordinator::select::{select, Candidate, SelectContext};
use paraspawn::mam::plan::Plan;
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::runtime::{CostModelKernel, Engine};

fn main() -> anyhow::Result<()> {
    let kernel = match Engine::cpu().and_then(|e| CostModelKernel::load(&e)) {
        Ok(k) => {
            println!("scoring backend: PJRT (batch {} x {} features)\n", k.k, k.f);
            Some(k)
        }
        Err(e) => {
            eprintln!("WARNING: artifacts unavailable ({e}); host fallback\n");
            None
        }
    };

    let candidates = vec![
        Candidate { method: Method::Merge, strategy: SpawnStrategy::Plain },
        Candidate { method: Method::Merge, strategy: SpawnStrategy::Single },
        Candidate { method: Method::Merge, strategy: SpawnStrategy::NodeByNode },
        Candidate { method: Method::Merge, strategy: SpawnStrategy::ParallelHypercube },
        Candidate { method: Method::Baseline, strategy: SpawnStrategy::ParallelHypercube },
    ];
    let cost = CostModel::mn5();

    // 1 -> 8 node expansion on a 112-core/node cluster.
    let mk_plan = |c: &Candidate| {
        let n = 8usize;
        let mut r = vec![0u32; n];
        r[0] = 112;
        Plan::new(0, c.method, c.strategy, (0..n).collect(), vec![112; n], r)
    };

    for expected_shrinks in [0.0, 1.0, 4.0] {
        let ctx = SelectContext { expected_shrinks };
        let (best, scores) = select(&candidates, mk_plan, &cost, &ctx, kernel.as_ref());
        println!("expected future shrinks: {expected_shrinks}");
        for (i, (c, s)) in candidates.iter().zip(&scores).enumerate() {
            let mark = if i == best { "  <== selected" } else { "" };
            println!(
                "  {:>8} + {:<10} predicted {:>8.3}s{mark}",
                c.method.name(),
                c.strategy.name(),
                s
            );
        }
        println!();
    }
    println!(
        "With shrinks on the horizon the parallel strategies win: their\n\
         expansion overhead is repaid by TS shrinks that avoid respawning."
    );
    Ok(())
}
