//! Paper-scale SWF trace replay under the pricing axis: the bundled
//! 2000+-job shrink-heavy trace (MN5-shaped, 32 nodes × 112 cores)
//! replayed end-to-end under the scalar TS/SS cost models, the exact
//! analytic per-event pricers, the cluster-state-aware stateful
//! pricers *and* the per-resize autotuner, reporting the makespan /
//! mean-wait / reconfig-node-seconds deltas per strategy.
//!
//! The acceptance bar this example demonstrates: the full replay (all
//! policy × pricing cells) finishes in well under ten seconds; the
//! analytic pricer reproduces the paper's qualitative result at
//! workload scale — TS yields strictly lower reconfiguration
//! node-seconds and makespan than SS on a shrink-heavy trace — the
//! stateful pricer never pays more reconfiguration node-seconds than
//! the canonical analytic one (on a warm cluster, expansions skip the
//! cold daemon rollout the canonical pair always charges, and victims
//! are picked by predicted cost) — and the autotuned arm, which argmins
//! the state-aware predicted cost over the TS-enabling
//! (strategy × method) grid at every resize event, never pays more
//! reconfiguration node-seconds than the best of the six fixed arms.
//!
//! ```bash
//! cargo run --release --example trace_replay
//! ```

use paraspawn::coordinator::sweep::ClusterKind;
use paraspawn::coordinator::wsweep::{
    analytic_pricers, auto_pricers, default_costs, kind_cost_model, run_workload_matrix,
    scalar_pricers, stateful_pricers, WorkloadMatrix, WorkloadSpec,
};
use paraspawn::rms::sched::{self, AnalyticPricer, ResizePricer, SchedPolicy};
use std::path::PathBuf;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let kind = ClusterKind::Mn5;
    let cluster = kind.cluster();
    let total_nodes = cluster.len();
    let cores = cluster.nodes.iter().map(|n| n.cores).min().unwrap_or(1);

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/replay2k.swf");
    let text = std::fs::read_to_string(&path)?;
    let mut jobs = sched::read_swf(&text, cores, total_nodes)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    sched::mark_malleable(&mut jobs, 0.7, 4, total_nodes, 2025);
    let n_jobs = jobs.len();
    println!(
        "replaying {n_jobs} jobs on {} ({} nodes x {} cores) under 7 pricing arms",
        cluster.name, total_nodes, cores
    );
    assert!(n_jobs >= 2000, "the bundled trace must stay paper-scale (got {n_jobs})");

    // A taste of the exact per-event prices the analytic arms charge —
    // the scalar models flatten all of these into two constants.
    let cost = kind_cost_model(kind);
    let mut ts = AnalyticPricer::ts(cluster.clone(), cost.clone());
    let mut ss = AnalyticPricer::ss(cluster.clone(), cost.clone());
    for (pre, post) in [(2usize, 8usize), (4, 16), (8, 2), (16, 4)] {
        if post > pre {
            println!(
                "  expand {pre:2} -> {post:2} nodes: {:.4} s per process",
                ts.expand_seconds(pre, post).map_err(anyhow::Error::msg)?
            );
        } else {
            println!(
                "  shrink {pre:2} -> {post:2} nodes: TS {:.6} s vs SS {:.4} s per process",
                ts.shrink_seconds(pre, post).map_err(anyhow::Error::msg)?,
                ss.shrink_seconds(pre, post).map_err(anyhow::Error::msg)?
            );
        }
    }

    let mut pricers = scalar_pricers(&default_costs());
    pricers.extend(analytic_pricers(&cost, None, 0));
    pricers.extend(stateful_pricers(&cost, None, 0));
    pricers.extend(auto_pricers(&cost, 0));
    let matrix = WorkloadMatrix {
        policies: vec![SchedPolicy::Fcfs, SchedPolicy::Malleable],
        pricers,
        workloads: vec![WorkloadSpec::new("replay2k", jobs)],
        ..WorkloadMatrix::for_kind(kind)
    };
    let t0 = Instant::now();
    let results = run_workload_matrix(&matrix, 4)?;
    let wall = t0.elapsed().as_secs_f64();
    print!("{}", results.summary_table().to_ascii());
    println!("\n{} cells in {wall:.2}s wall-clock", matrix.len());

    let get = |p: &str, c: &str| {
        results.cells[&("replay2k".to_string(), p.to_string(), c.to_string())].clone()
    };
    let ts_x = get("malleable", "TS-exact");
    let ss_x = get("malleable", "SS-exact");
    println!(
        "analytic TS vs SS (malleable policy): d_makespan {:+.1}s, d_mean_wait {:+.1}s, \
         d_reconfig_node_s {:+.1}",
        ts_x.makespan - ss_x.makespan,
        ts_x.mean_wait - ss_x.mean_wait,
        ts_x.reconfig_node_seconds - ss_x.reconfig_node_seconds,
    );
    let ts_s = get("malleable", "TS");
    let ss_s = get("malleable", "SS");
    println!(
        "scalar   TS vs SS (malleable policy): d_makespan {:+.1}s, d_mean_wait {:+.1}s, \
         d_reconfig_node_s {:+.1}",
        ts_s.makespan - ss_s.makespan,
        ts_s.mean_wait - ss_s.mean_wait,
        ts_s.reconfig_node_seconds - ss_s.reconfig_node_seconds,
    );
    let ts_st = get("malleable", "TS-state");
    println!(
        "stateful TS vs analytic TS (malleable policy): d_reconfig_node_s {:+.1} \
         ({} vs {} reconfigs)",
        ts_st.reconfig_node_seconds - ts_x.reconfig_node_seconds,
        ts_st.reconfigurations(),
        ts_x.reconfigurations(),
    );

    // The paper's qualitative result at workload scale, under exact
    // per-event pricing: cheap termination-based shrinks strictly beat
    // spawn-based shrinks on a shrink-heavy trace.
    assert!(ts_x.shrinks > 50, "the trace must be shrink-heavy (got {})", ts_x.shrinks);
    assert!(
        ts_x.reconfig_node_seconds < ss_x.reconfig_node_seconds,
        "TS reconfig node-seconds {} must be strictly below SS {}",
        ts_x.reconfig_node_seconds,
        ss_x.reconfig_node_seconds
    );
    assert!(
        ts_x.makespan < ss_x.makespan,
        "TS makespan {} must be strictly below SS {}",
        ts_x.makespan,
        ss_x.makespan
    );

    // State-aware pricing can only cut prices relative to the canonical
    // empty-cluster pair: the same resize on a warm node set is cheaper
    // (no cold daemon rollout) and the malleable policy additionally
    // picks the cheapest predicted shrink victims. At replay scale the
    // per-event savings dominate any trajectory divergence.
    assert!(
        ts_st.reconfig_node_seconds <= ts_x.reconfig_node_seconds,
        "stateful TS reconfig node-seconds {} must not exceed analytic TS {}",
        ts_st.reconfig_node_seconds,
        ts_x.reconfig_node_seconds
    );

    // The autotuned arm argmins over a grid that contains every fixed
    // arm's per-event choice, priced in the same cluster state — so at
    // replay scale it must not pay more reconfiguration node-seconds
    // than the best of the six fixed arms.
    let ss_st = get("malleable", "SS-state");
    let auto = get("malleable", "auto");
    let best_fixed = [&ts_s, &ss_s, &ts_x, &ss_x, &ts_st, &ss_st]
        .iter()
        .map(|r| r.reconfig_node_seconds)
        .fold(f64::INFINITY, f64::min);
    let decided = auto.decisions.iter().filter(|d| !d.is_empty()).count();
    println!(
        "auto vs best fixed arm (malleable policy): {:.1} vs {:.1} reconfig node-s \
         ({decided} jobs carry per-event decisions)",
        auto.reconfig_node_seconds, best_fixed
    );
    assert!(
        auto.reconfig_node_seconds <= best_fixed,
        "auto reconfig node-seconds {} must not exceed the best fixed arm {}",
        auto.reconfig_node_seconds,
        best_fixed
    );

    // Wall-clock budget (shared CI runners can override).
    let budget: f64 = std::env::var("PARASPAWN_TIME_BUDGET_S")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    assert!(wall < budget, "replay took {wall:.2}s (budget {budget:.1}s)");
    println!("OK: under the {budget:.1}-second budget, TS strictly beats SS");
    Ok(())
}
