//! Heterogeneous allocations (§4.2 / §5.3): the Iterative Diffusive
//! strategy on the NASP-like cluster (8 x 20-core IB nodes + 8 x 32-core
//! Ethernet nodes), including the paper's Table 2 worked example and a
//! real heterogeneous resize with per-step plan trace.
//!
//! ```bash
//! cargo run --release --example heterogeneous_resize
//! ```

use paraspawn::coordinator::figures::{describe_report, table2};
use paraspawn::coordinator::{run_reconfiguration, Scenario};
use paraspawn::mam::plan::{diffusive_trace, plan_steps, Plan};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::{AllocPolicy, Rms};
use paraspawn::topology::Cluster;

fn main() -> anyhow::Result<()> {
    // --- The paper's Table 2, regenerated from Eq. 4-8 -------------------
    println!("Table 2 (Iterative Diffusive worked example, Eq. 4-8):");
    print!("{}", table2().to_ascii());
    println!("(λ per Eq. 6; the paper's table has an off-by-one typo at s>=2\n\
              that affects no other column — see DESIGN.md)\n");

    // --- The diffusive plan for a real NASP resize ------------------------
    let rms = Rms::new(Cluster::nasp());
    let initial = rms.plan_allocation(2, AllocPolicy::BalancedTypes)?;
    println!("initial allocation: {:?}", initial.slots);
    let mut claimed = rms.clone();
    claimed.claim(&initial)?;
    let target = claimed.grow(&initial, 8, AllocPolicy::BalancedTypes)?;
    println!("target allocation:  {:?}", target.slots);

    let nodes: Vec<usize> = target.nodes();
    let a: Vec<u32> = target.slots.iter().map(|&(_, c)| c).collect();
    let mut r = vec![0u32; nodes.len()];
    for (i, &(node, cores)) in target.slots.iter().enumerate() {
        if initial.cores_on(node) > 0 {
            r[i] = cores.min(initial.cores_on(node));
        }
    }
    let plan = Plan::new(0, Method::Merge, SpawnStrategy::ParallelDiffusive, nodes, a, r);
    println!("\nA = {:?}\nR = {:?}\nS = {:?}", plan.a, plan.r, plan.s);
    println!("steps = {}", plan_steps(&plan));
    println!("\nstep trace:");
    for row in diffusive_trace(&plan) {
        println!(
            "  s={}  t_s={:<4} g_s={:<4} lambda_s={:<4} T_s={:<3} G_s={}",
            row.s, row.t, row.g, row.lambda, row.tt, row.gg
        );
    }
    println!("\nper-slot spawn tasks (slot -> [(step, gid, node, size)]):");
    let mut slots: Vec<_> = plan.assignments().into_iter().collect();
    slots.sort_by_key(|&(slot, _)| slot);
    for (slot, tasks) in slots {
        let t: Vec<String> = tasks
            .iter()
            .map(|t| {
                format!(
                    "(s{}, g{}, n{}, x{})",
                    t.step, t.group.gid, plan.nodes[t.group.node_idx], t.group.size
                )
            })
            .collect();
        println!("  slot {slot:<3} -> {}", t.join(" "));
    }

    // --- Execute the resize end to end ------------------------------------
    println!("\n--- executing 2 -> 8 node heterogeneous expansion ---");
    let s = Scenario::nasp(2, 8).with(Method::Merge, SpawnStrategy::ParallelDiffusive);
    let report = run_reconfiguration(&s)?;
    println!("{}", describe_report(&report));

    println!("\n--- and the TS shrink back, 8 -> 2 nodes ---");
    let s = Scenario { prepare_parallel: true, ..Scenario::nasp(8, 2) }
        .with(Method::Merge, SpawnStrategy::Plain);
    let report_ts = run_reconfiguration(&s)?;
    println!("{}", describe_report(&report_ts));
    Ok(())
}
