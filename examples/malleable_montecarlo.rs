//! End-to-end driver (E8 in DESIGN.md): a malleable Monte-Carlo π
//! application whose per-iteration compute runs through the **full
//! three-layer stack** — the AOT-compiled Pallas `pi` kernel (L1) inside
//! the JAX model (L2), executed from the Rust coordinator via PJRT (L3) —
//! while an RMS trace expands and shrinks the job at runtime:
//!
//!   4 -> 8 nodes (Merge + Hypercube) -> 12 (Merge + Diffusive)
//!     -> 6 (Merge = TS shrink) -> 10 (Merge + Hypercube) -> 4 (TS)
//!
//! Logs the π estimate per iteration (the "loss curve" of this workload)
//! and the reconfiguration breakdowns; the run is recorded in
//! EXPERIMENTS.md §E8.
//!
//! ```bash
//! make artifacts && cargo run --release --example malleable_montecarlo
//! ```

use paraspawn::app::{self, AppSpec, HostPiEval, PiEval, ResizeEvent};
use paraspawn::config::{CostModel, SimConfig};
use paraspawn::mam::{Method, SpawnStrategy};
use paraspawn::rms::{AllocPolicy, Rms};
use paraspawn::runtime::{Engine, PiKernel};
use paraspawn::simmpi::World;
use paraspawn::topology::Cluster;
use paraspawn::util::csvout::fmt_time;
use std::sync::{Arc, Mutex};

fn main() -> anyhow::Result<()> {
    // A 12-node, 8-core cluster keeps the end-to-end run snappy while
    // exercising every reconfiguration path.
    let cluster = Cluster::homogeneous(
        "demo",
        12,
        8,
        paraspawn::topology::LinkKind::InfiniBand100,
    );
    let mut rms = Rms::new(cluster.clone());
    let a4 = rms.plan_allocation(4, AllocPolicy::WholeNodes)?;
    rms.claim(&a4)?;
    let a8 = rms.grow(&a4, 8, AllocPolicy::WholeNodes)?;
    let a12 = rms.grow(&a8, 12, AllocPolicy::WholeNodes)?;
    let a6 = rms.shrink(&a12, 6);
    let a10 = rms.grow(&a6, 10, AllocPolicy::WholeNodes)?;
    let a4_final = rms.shrink(&a10, 4);

    // L1/L2 through PJRT; falls back to a host evaluator (with a warning)
    // when artifacts are missing.
    let pi_eval: Arc<dyn PiEval> = match Engine::cpu().and_then(|e| PiKernel::load(&e)) {
        Ok(k) => {
            println!("π kernel: AOT Pallas via PJRT (batch {})", k.batch());
            Arc::new(k)
        }
        Err(e) => {
            eprintln!("WARNING: artifacts unavailable ({e}); using host fallback");
            Arc::new(HostPiEval)
        }
    };

    let m = Method::Merge;
    use SpawnStrategy::*;
    let trace = vec![
        ResizeEvent::new(a8, m, ParallelHypercube),
        ResizeEvent::new(a12, m, ParallelDiffusive),
        ResizeEvent::new(a6, m, Plain), // TS shrink
        ResizeEvent::new(a10, m, ParallelHypercube),
        ResizeEvent::new(a4_final, m, Plain), // TS
    ];

    let estimates = Arc::new(Mutex::new(Vec::new()));
    let est2 = estimates.clone();
    let spec = Arc::new(AppSpec {
        iters_per_epoch: 5,
        work_per_iter: 2000.0,
        points_per_iter: 2048,
        trace,
        data_bytes: 8 << 20, // redistribute 8 MiB of application state
        pi_eval,
        observer: Some(Arc::new(move |epoch, iter, pi, vclock| {
            est2.lock().unwrap().push((epoch, iter, pi, vclock));
        })),
    });

    let world = World::new(cluster, SimConfig { cost: CostModel::mn5(), ..Default::default() });
    app::run_malleable(&world, &a4, spec)?;

    println!("\niter trace (epoch, iter, ranks-era, π estimate, virtual clock):");
    for (epoch, iter, pi, vclock) in estimates.lock().unwrap().iter() {
        println!("  e{epoch} i{iter}:  π ≈ {pi:.4}   t={}", fmt_time(*vclock));
    }

    println!("\nreconfigurations:");
    for rec in world.metrics.reconfigs() {
        let phases: Vec<String> = rec
            .phases
            .iter()
            .map(|(p, d)| format!("{}={}", p.name(), fmt_time(*d)))
            .collect();
        println!(
            "  epoch {}: {} {} {} -> {} ranks in {}   [{}]",
            rec.epoch,
            rec.method,
            rec.strategy,
            rec.ns,
            rec.nt,
            fmt_time(rec.total()),
            phases.join(", ")
        );
    }

    let returns = world.metrics.node_returns();
    println!("\nnodes returned to the RMS: {}", returns.len());
    for r in &returns {
        println!("  node {} at t={}", r.node, fmt_time(r.at));
    }
    assert!(returns.len() >= 12 - 4, "TS shrinks must return nodes");

    let final_pi = estimates.lock().unwrap().last().map(|&(_, _, pi, _)| pi).unwrap();
    println!("\nfinal π estimate: {final_pi:.4} (true: {:.4})", std::f64::consts::PI);
    Ok(())
}
