//! Batch-scheduler demo: the system-level payoff of cheap TS shrinks.
//!
//! Calibrates TS/SS reconfiguration-cost models from the sweep engine
//! (spawn-strategy medians, the paper's microbenchmarks), then runs a
//! policy × cost-model grid — FCFS, EASY backfilling and the
//! malleability-aware policy — over a synthetic workload on the MN5
//! cluster, printing makespan/mean-wait per cell.
//!
//! ```bash
//! cargo run --release --example batch_sched
//! ```

use paraspawn::coordinator::sweep::ClusterKind;
use paraspawn::coordinator::wsweep::{
    calibrated_costs, run_workload_matrix, scalar_pricers, WorkloadMatrix, WorkloadSpec,
};
use paraspawn::rms::workload::synthetic_workload;

fn main() -> anyhow::Result<()> {
    let kind = ClusterKind::Mn5;
    let total_nodes = kind.cluster().len();

    // Microbenchmark -> cost model: medians measured on the sweep pool.
    let costs = calibrated_costs(kind, 5, 0xF16, 4)?;
    for c in &costs {
        println!(
            "calibrated {}: expand {:.4}s, shrink {:.6}s",
            c.label, c.model.expand_cost, c.model.shrink_cost
        );
    }

    let matrix = WorkloadMatrix {
        pricers: scalar_pricers(&costs),
        workloads: vec![WorkloadSpec::new(
            "synthetic",
            synthetic_workload(50, total_nodes, 0.6, 2025),
        )],
        ..WorkloadMatrix::for_kind(kind)
    };
    let results = run_workload_matrix(&matrix, 4)?;
    print!("{}", results.summary_table().to_ascii());

    let get = |p: &str, c: &str| {
        results.cells[&("synthetic".to_string(), p.to_string(), c.to_string())].clone()
    };
    let fcfs = get("fcfs", "TS");
    let drm_ts = get("malleable", "TS");
    let drm_ss = get("malleable", "SS");
    println!(
        "\nmalleable+TS improves makespan by {:.1}% over FCFS ({:.1}% for malleable+SS)",
        100.0 * (1.0 - drm_ts.makespan / fcfs.makespan),
        100.0 * (1.0 - drm_ss.makespan / fcfs.makespan),
    );
    Ok(())
}
